"""The paper's six benchmarks as DES workload profiles (Table 1 + §5).

Each profile carries the paper's Table 1 parameters (work-items, memory
footprint, read:write buffer ratio, local work size) and a calibration of the
two Coexecution Units (CPU = i5-7500 4C, GPU = HD Graphics 630):

* ``ratio``  — GPU/CPU throughput on uniform data (§5.3 gives 13.5, 4.8 and
               4.6 for Gaussian, Mandelbrot and Ray; the others are
               calibrated to the paper's HGuided speedups: Taylor ≈ 1.95,
               Rap = 2.46 ⇒ CPU is 1.46× the iGPU on Rap).
* ``alpha``  — the GPU's irregularity exponent (divergence sensitivity);
               1.0 for regular kernels.
* weights    — per-workgroup cost profile: real Mandelbrot escape-iteration
               counts, a synthetic Ray scene-density field, Rap row lengths.

DES items are *workgroups* (Table 1 local work size), not single work-items:
the scheduler granularity is exactly one workgroup, as in the reference
runtime. The GPU processes the full problem in ~10 s (paper §5.3).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from .sim import Workload
from .units import SimUnit

GPU_SOLO_SECONDS = 10.0


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    """Table 1 row + device calibration.

    ``capacity_ratio`` is the §5.3 compute-capacity GPU/CPU ratio (13.5,
    4.8, 4.6 for Gaussian/Mandelbrot/Ray) — it governs *small*,
    cache-resident problem sizes. ``bw_ratio`` is the asymptotic ratio once
    the working set spills to shared DRAM and both devices ride the same
    memory bus (≈ 2 for the memory-bound regular kernels). The effective
    ratio at a given size blends the two by working-set size; this is what
    makes the paper's Fig. 5 speedups, Fig. 7 EDP and §5.3 turning points
    mutually consistent.
    """

    name: str
    work_items: int            # Table 1 (N x 1e5)
    local_work_size: int       # Table 1
    mem_mib: float             # Table 1
    read_write: tuple[int, int]  # Table 1 read:write buffers
    uses_local_mem: bool       # Table 1
    capacity_ratio: float      # GPU/CPU at cache-resident sizes
    bw_ratio: float            # GPU/CPU once DRAM-bandwidth-bound
    gpu_alpha: float           # divergence exponent of the iGPU
    irregular: bool

    @property
    def groups(self) -> int:
        return max(1, self.work_items // self.local_work_size)

    def effective_ratio(self, working_set_bytes: float,
                        cache_transition_bytes: float = 8 * 2**20) -> float:
        """Blend capacity→bandwidth ratio as the working set spills caches."""
        f = 1.0 / (1.0 + working_set_bytes / cache_transition_bytes)
        return self.bw_ratio + (self.capacity_ratio - self.bw_ratio) * f


SPECS: dict[str, BenchSpec] = {
    "gaussian": BenchSpec("gaussian", 262 * 10**5, 128, 195.0, (2, 1), False,
                          capacity_ratio=13.5, bw_ratio=2.0,
                          gpu_alpha=1.0, irregular=False),
    "matmul": BenchSpec("matmul", 237 * 10**5, 64, 264.0, (2, 1), True,
                        capacity_ratio=3.3, bw_ratio=1.75,
                        gpu_alpha=1.0, irregular=False),
    "taylor": BenchSpec("taylor", 10 * 10**5, 64, 46.0, (3, 2), True,
                        capacity_ratio=1.05, bw_ratio=1.05,
                        gpu_alpha=1.0, irregular=False),
    "ray": BenchSpec("ray", 94 * 10**5, 128, 35.0, (1, 1), True,
                     capacity_ratio=4.6, bw_ratio=4.6,
                     gpu_alpha=2.0, irregular=True),
    "rap": BenchSpec("rap", 5 * 10**5, 128, 6.0, (2, 1), False,
                     capacity_ratio=0.685, bw_ratio=0.685,
                     gpu_alpha=1.1, irregular=True),
    "mandelbrot": BenchSpec("mandelbrot", 703 * 10**5, 256, 1072.0, (0, 1),
                            False, capacity_ratio=4.8, bw_ratio=4.8,
                            gpu_alpha=1.5, irregular=True),
}


# ---------------------------------------------------------------------------
# Irregular weight profiles (per workgroup, mean normalized to 1.0)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _mandelbrot_profile(groups: int, max_iter: int = 256) -> np.ndarray:
    """Real escape-iteration counts over the classic viewport, row-major,
    resampled to `groups` workgroups."""
    side = 512
    re = np.linspace(-2.2, 0.8, side)[None, :]
    im = np.linspace(-1.4, 1.4, side)[:, None]
    c = re + 1j * im
    z = np.zeros_like(c)
    iters = np.full(c.shape, max_iter, dtype=np.float64)
    alive = np.ones(c.shape, dtype=bool)
    for k in range(max_iter):
        z[alive] = z[alive] ** 2 + c[alive]
        esc = alive & (np.abs(z) > 2.0)
        iters[esc] = k
        alive &= ~esc
    flat = iters.ravel()
    idx = np.linspace(0, flat.size - 1, groups).astype(int)
    w = flat[idx] + 1.0
    return w / w.mean()


@functools.lru_cache(maxsize=None)
def _ray_profile(groups: int) -> np.ndarray:
    """Synthetic scene density: cheap background + heavy object blobs.

    Calibrated so that mean(w)=1 with a bimodal shape (80 % light rays at
    ~0.45, 20 % heavy intersections at ~3.2) — with the iGPU's alpha=2 this
    yields the paper's Ray speedup of ≈1.48 over GPU-only.
    """
    rng = np.random.default_rng(7)
    w = np.full(groups, 0.45)
    # spatial coherence: heavy objects occupy contiguous scanline runs of
    # ~2 % of the image each, covering 20 % of all rays. The exact bimodal
    # mass (80 % @ 0.45, 20 % @ 3.2 ⇒ mean 1.0, mean(w²) ≈ 2.21) is what
    # yields the paper's Ray co-execution speedup of ≈ 1.48 with alpha = 2.
    run = max(1, groups // 50)
    heavy_runs = max(1, int(0.20 * groups / run))
    starts = rng.choice(groups - run, size=heavy_runs, replace=False)
    for s in starts:
        w[s:s + run] = 3.2
    return w / w.mean()


@functools.lru_cache(maxsize=None)
def _rap_profile(groups: int) -> np.ndarray:
    """Resource-allocation rows of linearly growing length (triangular
    work distribution — the classic irregular RAP shape)."""
    w = np.linspace(0.2, 1.8, groups)
    return w / w.mean()


def _weights(spec: BenchSpec) -> np.ndarray | None:
    if not spec.irregular:
        return None
    if spec.name == "mandelbrot":
        return _mandelbrot_profile(spec.groups)
    if spec.name == "ray":
        return _ray_profile(spec.groups)
    if spec.name == "rap":
        return _rap_profile(spec.groups)
    raise KeyError(spec.name)


# ---------------------------------------------------------------------------
# Public factory
# ---------------------------------------------------------------------------

def paper_workload(name: str, *, size_scale: float = 1.0
                   ) -> tuple[Workload, SimUnit, SimUnit]:
    """Build (workload, cpu_unit, gpu_unit) for one registered workload.

    Dispatches through the :mod:`repro.api.registry` workload registry, so
    `name` may be any registered profile — the paper's six benchmarks
    register below, third-party profiles via
    :func:`repro.api.register_workload`. ``size_scale`` scales the problem
    size (Fig. 8 scalability sweeps); device speeds are fixed, so GPU-solo
    time scales linearly with it.

    Args:
        name: registered workload profile name.
        size_scale: problem-size multiplier.

    Returns:
        ``(workload, cpu_unit, gpu_unit)``.

    Raises:
        KeyError: unknown profile name.
    """
    from repro.api.registry import build_workload

    return build_workload(name, size_scale=size_scale)


def _build_paper_workload(name: str, *, size_scale: float = 1.0
                          ) -> tuple[Workload, SimUnit, SimUnit]:
    """Registry factory for one paper benchmark (Table 1 calibration)."""
    spec = SPECS[name]
    groups = max(16, int(spec.groups * size_scale))
    weights = _weights(spec)
    if weights is not None and groups != len(weights):
        idx = np.linspace(0, len(weights) - 1, groups).astype(int)
        weights = weights[idx]

    bytes_per_group = spec.mem_mib * 2**20 / spec.groups
    r, w = spec.read_write
    frac_out = w / max(r + w, 1)
    wl = Workload(
        name=spec.name,
        total=groups,
        bytes_in_per_item=bytes_per_group * (1 - frac_out),
        bytes_out_per_item=bytes_per_group * frac_out,
        working_set_bytes=spec.mem_mib * 2**20 * size_scale,
        weights=weights,
        # only MatMul has the temporal reuse that LLC invalidations destroy
        contention_scale=1.0 if spec.uses_local_mem and spec.name == "matmul"
        else 0.0,
    )
    gpu_speed = spec.groups / GPU_SOLO_SECONDS  # uniform-data groups/s
    ratio = spec.effective_ratio(wl.working_set_bytes)
    cpu = SimUnit("cpu", "cpu", speed=gpu_speed / ratio, alpha=1.0,
                  setup_s=1e-3)
    gpu = SimUnit("gpu", "gpu", speed=gpu_speed, alpha=spec.gpu_alpha,
                  setup_s=3e-3)
    return wl, cpu, gpu


def effective_shares(wl: Workload, cpu: SimUnit, gpu: SimUnit,
                     *, hint_error: float = 0.0) -> list[float]:
    """Per-application computing-power hint (the paper's ``dist(0.35)``).

    The programmer measures each device's throughput *on this workload*
    (alpha-inflated for irregular data) and passes the CPU's share; a
    positive ``hint_error`` over-estimates the CPU, as off-line estimates
    typically drift — HGuided absorbs the drift, Static cannot (§2).
    """
    def eff_speed(u: SimUnit) -> float:
        if wl.weights is None or u.alpha == 1.0:
            return u.speed
        inflation = float(np.mean(wl.weights ** u.alpha))
        return u.speed / max(inflation, 1e-12)

    s_cpu, s_gpu = eff_speed(cpu), eff_speed(gpu)
    share = s_cpu / (s_cpu + s_gpu)
    share = min(0.9, share * (1.0 + hint_error))
    return [share, 1.0 - share]


REGULAR = ("gaussian", "matmul", "taylor")
IRREGULAR = ("mandelbrot", "rap", "ray")
ALL_BENCHMARKS = REGULAR + IRREGULAR


def _register_builtin_workloads() -> None:
    """Idempotently register the paper's six profiles (import side)."""
    from repro.api.registry import register_workload

    for bench in ALL_BENCHMARKS:
        register_workload(bench,
                          functools.partial(_build_paper_workload, bench),
                          fields=("size_scale",), overwrite=True)


_register_builtin_workloads()
