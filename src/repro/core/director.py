"""Director — blocking-launch compatibility facade over the CoexecEngine.

Historically the Director spawned one management thread per Coexecution
Unit on *every* launch and joined them before returning — the per-launch
engine the paper's antecedent EngineCL shows cannot keep management
overhead under 1%. The execution core now lives in
:class:`~.engine.CoexecEngine` (persistent worker threads, multi-tenant
launch queue); the Director survives as the thin blocking wrapper that
mirrors the paper's Fig. 2a vocabulary: configure the units, run the
Commander protocol over one index space, merge the results.

The memory-model semantics are real (see :mod:`repro.core.dataplane`):
* USM     — units compute on zero-copy views of the shared inputs and
            write their slices directly into one shared host output
            array (the logically-unified allocation; no staging copies).
* BUFFERS — each package's inputs are staged with ``device_put`` and its
            output chunk copied back through a separate buffer before the
            merge into the host container (explicit, counted copies).
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from .engine import CoexecEngine
from .memory import MemoryModel
from .package import Package
from .scheduler import Scheduler
from .units import JaxUnit


class Director:
    """Configures units, drives one blocking co-execution at a time.

    Owns a lazily-started persistent engine; repeated ``launch`` calls
    reuse the same worker threads (and the same SpeedBoard, so adaptive
    policies keep their learned speeds across launches).
    """

    def __init__(self, units: Sequence[JaxUnit], *,
                 memory: MemoryModel = MemoryModel.USM):
        from repro.api.spec import CoexecSpec, MemorySpec

        self.engine = CoexecEngine(
            units, spec=CoexecSpec(memory=MemorySpec(model=memory.value)))

    @property
    def units(self) -> list[JaxUnit]:
        return self.engine.units

    @property
    def memory(self) -> MemoryModel:
        return self.engine.memory

    @property
    def board(self):
        return self.engine.board

    def launch(self, scheduler: Scheduler, kernel: Callable,
               inputs: Sequence[np.ndarray], out: np.ndarray,
               *, adaptive: bool = True) -> list[Package]:
        """Blocking co-execution of `kernel` over the whole index space.

        kernel(offset_scalar, *chunks) -> chunk_out ; the chunks are
        staged from `inputs` by the engine's data plane per the configured
        memory model (and per the kernel's declared argument semantics
        when it is a :class:`~repro.core.dataplane.CoexecKernel`).
        """
        self.engine.start()
        handle = self.engine.submit(scheduler, kernel, inputs, out,
                                    adaptive=adaptive)
        handle.result()          # re-raises the first package error, if any
        return handle.stats.packages

    def shutdown(self) -> None:
        self.engine.shutdown()

    def __enter__(self) -> "Director":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def __del__(self) -> None:
        # stop the (daemon) workers of a dropped Director so per-request
        # Director construction cannot accumulate parked threads
        try:
            engine = self.engine
        except AttributeError:
            return               # __init__ never got to set the engine
        try:
            engine.shutdown(wait=False)
        except RuntimeError:
            pass                 # interpreter teardown: threading gone
        except Exception:
            # anything else is a real bug in the shutdown path — keep it
            # visible instead of silently dropping it (raising from
            # __del__ would only reach sys.unraisablehook)
            import logging

            logging.getLogger(__name__).exception(
                "unexpected error shutting down a dropped Director")
