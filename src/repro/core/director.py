"""Director + Commander loop — the real (threaded) execution engine.

Mirrors the paper's execution model (Fig. 2a): the Director configures the
Coexecution Units and owns the Commander, which packages work, emits tasks
and collects completion events. Each unit gets a management thread; the
application-facing `launch` call blocks until the whole index space has been
computed and collected, while everything inside runs asynchronously.

The memory model determines collection:
* USM     — units write their slices directly into one shared host output
            array (the logically-unified allocation); collection is a no-op
            beyond the event itself.
* BUFFERS — each package's output chunk is returned as a separate buffer and
            the Commander merges it into the host container (explicit copy).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from .memory import MemoryModel
from .package import Package, validate_cover
from .profiler import SpeedBoard
from .scheduler import HGuidedScheduler, Scheduler
from .units import JaxUnit


class Director:
    """Configures units, runs the Commander loop, merges results."""

    def __init__(self, units: Sequence[JaxUnit], *,
                 memory: MemoryModel = MemoryModel.USM):
        if not units:
            raise ValueError("need at least one Coexecution Unit")
        self.units = list(units)
        self.memory = memory
        self.board = SpeedBoard(len(units),
                                hints=[u.speed_hint for u in units])

    def launch(self, scheduler: Scheduler, kernel: Callable,
               inputs: Sequence[np.ndarray], out: np.ndarray,
               *, adaptive: bool = True) -> list[Package]:
        """Blocking co-execution of `kernel` over the whole index space.

        kernel(offset_scalar, *chunks) -> chunk_out ; chunks are the package
        slices of `inputs` (padded to the unit's size bucket).
        """
        lock = threading.Lock()          # guards the scheduler
        errors: list[BaseException] = []
        done: list[Package] = []

        def manager(unit_idx: int) -> None:
            unit = self.units[unit_idx]
            while True:
                with lock:
                    if adaptive and isinstance(scheduler, HGuidedScheduler):
                        for i, s in enumerate(self.board.speeds()):
                            scheduler.update_speed(i, s)
                    pkg = scheduler.next_package(unit_idx)
                if pkg is None:
                    return
                pkg.t_issue = time.perf_counter()
                try:
                    chunk = unit.run_package(kernel, pkg.offset, pkg.size,
                                             inputs)
                except BaseException as e:  # surface on the caller thread
                    errors.append(e)
                    return
                pkg.t_complete = time.perf_counter()
                # collection: USM writes in place into the shared container;
                # BUFFERS performs an explicit merge copy (same destination,
                # but modeled/accounted as a copy, and chunk is a separate
                # buffer either way on this substrate).
                out[pkg.offset:pkg.offset + pkg.size] = chunk
                pkg.t_collected = time.perf_counter()
                self.board.record(unit_idx, pkg.size,
                                  max(pkg.t_complete - pkg.t_issue, 1e-9))
                with lock:
                    done.append(pkg)

        threads = [threading.Thread(target=manager, args=(i,),
                                    name=f"counit-{self.units[i].name}",
                                    daemon=True)
                   for i in range(len(self.units))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        validate_cover(done, scheduler.total)
        return done
