"""Memory models of the Coexecutor Runtime (paper §3.1, Fig. 2b).

Two strategies, selectable per launch (and combinable — each buffer is
governed by its own model, as in the paper):

* ``USM``     — one logical allocation shared by all Coexecution Units.
                In JAX this is a single globally-sharded ``jax.Array`` (or a
                host numpy array that device slices view in-place): result
                collection is (nearly) free; inputs need no staging copy.
* ``BUFFERS`` — per-package disjoint buffers: inputs are staged to the unit
                (``device_put`` of the slice) and outputs copied back into
                the host container. Costs one H2D + one D2H proportional to
                the package bytes, plus a fixed submission overhead.

Two layers consume the model selection:

* the **cost model** below drives the discrete-event simulator (paper
  reproduction) — bandwidths calibrated to the paper's platform (Kaby
  Lake iGPU sharing LLC/DRAM with the CPU), overridable for TPU-class
  parts;
* the **real data plane** (:mod:`repro.core.dataplane`) implements the
  semantics on the live engine: ``MemoryModel.USM`` selects zero-copy
  shared-array movement with in-place collection, ``MemoryModel.BUFFERS``
  per-package ``device_put`` staging and copy-back, both instrumented
  with copy/dispatch counters surfaced in launch stats.
"""
from __future__ import annotations

import dataclasses
import enum


class MemoryModel(enum.Enum):
    """Package data-movement strategy (paper §3.1): USM or Buffers.

    The enum selects both the DES cost model (:class:`MemoryCosts`) and
    the real engine's data plane
    (:func:`repro.core.dataplane.make_plane`).
    """

    USM = "usm"
    BUFFERS = "buffers"


@dataclasses.dataclass(frozen=True)
class MemoryCosts:
    """Per-package data-movement cost parameters (seconds, bytes/second)."""

    # fixed host-side cost to emit one package. For BUFFERS this includes
    # SYCL buffer + accessor re-creation and DAG node insertion per package
    # (the dominant cost the paper observes for "Gaussian with Buffers" at
    # 200 packages); for USM only a queue submit is paid.
    submit_overhead_s: float = 250e-6
    buffer_submit_overhead_s: float = 15e-3
    # staging bandwidth for the BUFFERS model (effective SYCL buffer copy
    # bandwidth incl. first-touch paging; H2D and D2H assumed symmetric)
    copy_bw_Bps: float = 2e9
    # USM collection: pointer handoff + cacheline ping, effectively flat
    usm_collect_s: float = 50e-6
    buffer_collect_overhead_s: float = 6e-3
    # LLC/DRAM contention: dimensionless slowdown per byte of *simultaneous*
    # working set beyond the LLC capacity — reproduces the paper's MatMul
    # Fig. 8 observation (co-execution degrades to GPU-only for very large
    # matrices because the iGPU thrashes the shared LLC).
    llc_bytes: float = 6 * 2**20
    contention_per_B: float = 3.0e-10

    def launch_cost(self, model: MemoryModel, in_bytes: int) -> float:
        """Host-side cost to issue one package with `in_bytes` of inputs."""
        if model is MemoryModel.USM:
            return self.submit_overhead_s
        return self.buffer_submit_overhead_s + in_bytes / self.copy_bw_Bps

    def collect_cost(self, model: MemoryModel, out_bytes: int) -> float:
        """Host-side cost to collect one package's `out_bytes` of outputs."""
        if model is MemoryModel.USM:
            return self.usm_collect_s
        return self.buffer_collect_overhead_s + out_bytes / self.copy_bw_Bps

    def contention_penalty(self, working_set_bytes: float) -> float:
        """Multiplicative slowdown applied while >1 unit is busy and the
        combined working set spills the shared LLC."""
        spill = max(0.0, working_set_bytes - self.llc_bytes)
        return 1.0 + spill * self.contention_per_B


# TPU-class preset: packages move over PCIe/DCN to a pod slice. Used by the
# hetero/ layer when modeling inter-group package costs.
TPU_MEMORY_COSTS = MemoryCosts(
    submit_overhead_s=30e-6,
    copy_bw_Bps=50e9,          # ICI-attached host staging
    usm_collect_s=2e-6,        # sharded jax.Array: no host copy
    llc_bytes=128 * 2**20,     # CMEM-scale shared capacity
    contention_per_B=2e-12,
)
