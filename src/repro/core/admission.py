"""Cross-launch admission control for the persistent engine (and DES).

PR 1's :class:`~.engine.CoexecEngine` is multi-tenant but strictly FIFO:
packages of concurrent launches drain in submit order, one launch at a
time, with no limit on how much work callers may pile up. EngineCL
(arXiv:1805.02755) and the time-constrained co-execution follow-up
(arXiv:2010.12607) both observe that under dynamic load the *queueing
discipline* — not just the intra-launch split — determines throughput and
fairness. This module is that discipline, factored out of the engine so
the exact same policies run on the real worker threads and on the
discrete-event simulator:

* **Weighted-fair queueing** (``policy="wfq"``) — deficit-round-robin over
  *packages* across tenants: each tenant accrues credit proportional to
  its weight and spends it per work-item served, so two tenants at
  weights 2:1 see a 2:1 completed-item ratio while both are backlogged.
  ``policy="fifo"`` reproduces PR 1's behavior bit-for-bit.
* **Launch fusion** (``fuse=True``) — small concurrent launches with the
  same kernel and shapes are staged for a short batching window and
  coalesced into one fused launch whose index space is *members*; N tiny
  requests then cost ~one dispatch per unit instead of N full scheduler
  drains. The caller supplies the materializer (the engine stacks inputs
  and vmaps the kernel; the simulator concatenates workloads) and
  de-multiplexes on completion.
* **Deadline-aware admission** (``policy="edf"``) — WFQ's deficit
  machinery with the scan ordered earliest-absolute-deadline-first and
  rank-based credit boosts for the flows nearest their deadline
  (``edf_boost``), the time-constrained setting of arXiv:2010.12607.
* **Load shedding** (``shed=True``) — :meth:`AdmissionController.offer`
  runs a virtual single-server finish-time estimator over the offered
  arrivals (capacity ``shed_rate`` items/s); a launch whose estimated
  finish misses its deadline is rejected up to a bounded fraction of the
  offered load (``shed_budget``), so overload degrades gracefully
  instead of collapsing every tenant's p99. Decisions depend only on
  the arrival sequence and the config, never on the execution substrate,
  which is what makes the accept/shed sequence reproducible bit-for-bit
  between the real engine and the DES.
* **Backpressure** (``max_inflight``) — a cap on admitted-but-unfinished
  launches; :meth:`AdmissionController.has_capacity` lets the engine's
  ``submit(..., block=True)`` path wait instead of queueing unboundedly.

The controller is deliberately *not* thread-safe: the engine calls it
under its condition variable, the simulator single-threaded. Entries are
duck-typed — anything with ``scheduler``, ``tenant``, ``weight`` and
optionally ``fuse_key`` / ``slots`` / ``failed`` / ``deadline``
attributes schedules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

from .package import Package

ADMISSION_POLICIES = ("fifo", "wfq", "edf")


class AdmissionFull(RuntimeError):
    """Raised by non-blocking submission when the engine is at capacity.

    Signals that :class:`AdmissionConfig.max_inflight` launches are already
    admitted and unfinished; the caller should retry later, shed load, or
    submit with ``block=True`` to wait for a slot.
    """


class LaunchShed(AdmissionFull):
    """The admission layer rejected a launch to protect its SLO budget.

    Raised from :meth:`~repro.core.engine.LaunchHandle.result` /
    returned from :meth:`~repro.core.engine.LaunchHandle.exception`
    *immediately* — a shed launch's handle is resolved at submit time,
    never left to dangle until a wait timeout. Subclasses
    :class:`AdmissionFull` so existing at-capacity handlers keep working.
    """


def fusion_bucket(total: int) -> int:
    """Smallest power of two ≥ ``total`` (the bucketed-fusion pad size).

    Args:
        total: a launch's index-space size in work-items.

    Returns:
        The power-of-2 bucket the launch pads up to under
        ``fuse_buckets=True`` (1 for non-positive totals).
    """
    return 1 << max(int(total) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Tuning knobs of the admission layer.

    Args:
        policy: ``"fifo"`` (PR 1 behavior: strict submit order),
            ``"wfq"`` (deficit-round-robin weighted fairness per tenant),
            or ``"edf"`` (WFQ credit with the scan ordered
            earliest-deadline-first and starved flows refilled with
            deadline-rank boosts).
        fuse: stage fusion-eligible launches and coalesce concurrent ones
            into shared dispatches.
        fuse_threshold: largest launch (work-items) eligible for fusion;
            bigger launches keep both units busy on their own and gain
            nothing from batching.
        fuse_limit: maximum members per fused batch — a full group is
            materialized immediately without waiting for the window.
        fuse_wait_s: batching window. A staged group is held until this
            much time passed since its first member (or the group is
            full/force-flushed); 0 fuses exactly the launches that are
            concurrently queued, which is what the simulator uses.
        max_inflight: cap on admitted-but-unfinished launches (fused
            members each count as one); ``None`` means unbounded.
        quantum: DRR credit granted per round in work-items; ``None``
            derives it from the active schedulers' package-size hints.
        preempt: let WFQ reclaim credit mid-launch by capping the
            per-pull package size of an over-served tenant at its
            remaining credit. Without it, deficit round robin lets one
            pull overdraft by a whole (possibly huge) package — surplus
            round robin — which is fair in the long run but bursty at
            short horizons. Inert under ``policy="fifo"`` (there is no
            credit to reclaim).
        fuse_buckets: widen fusion eligibility to near-identical shapes:
            launches whose index spaces fall in the same power-of-2 size
            bucket (:func:`fusion_bucket`) share a fuse key and pad up
            to the bucket size, so mixed real-world traffic still fuses
            instead of degenerating to singleton dispatches.
        slo_ms: default per-launch SLO in milliseconds — a launch
            submitted without an explicit deadline gets
            ``t_submit + slo_ms/1e3``; ``None`` leaves deadlines unset.
        shed: reject launches whose estimated finish time misses their
            deadline (see :meth:`AdmissionController.offer`), up to the
            rejection budget. Requires ``shed_rate`` to have any effect.
        shed_budget: bounded rejection fraction — at most this share of
            the offered launches is ever shed; past the budget overload
            degrades gracefully (launches are admitted late rather than
            rejected).
        shed_rate: the admission estimator's capacity in work-items per
            second (a virtual single server); ``None`` disables the
            estimator (nothing is ever shed).
        edf_boost: credit-boost strength for the EDF refill — a starved
            flow at deadline rank ``r`` (0 = most urgent) earns credit
            at ``weight * (1 + edf_boost / (r + 1))``, so the launches
            nearest their deadline pull ahead deterministically.

    Raises:
        ValueError: on an unknown policy or non-positive limits.
    """

    policy: str = "fifo"
    fuse: bool = False
    fuse_threshold: int = 1 << 12
    fuse_limit: int = 64
    fuse_wait_s: float = 0.002
    max_inflight: Optional[int] = None
    quantum: Optional[int] = None
    preempt: bool = False
    fuse_buckets: bool = False
    slo_ms: Optional[float] = None
    shed: bool = False
    shed_budget: float = 0.25
    shed_rate: Optional[float] = None
    edf_boost: float = 1.0

    def __post_init__(self) -> None:
        if self.policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {self.policy!r}; "
                             f"choose from {ADMISSION_POLICIES}")
        if self.fuse_threshold <= 0 or self.fuse_limit <= 0:
            raise ValueError("fuse_threshold and fuse_limit must be positive")
        if self.fuse_wait_s < 0:
            raise ValueError("fuse_wait_s must be non-negative")
        if self.max_inflight is not None and self.max_inflight <= 0:
            raise ValueError("max_inflight must be positive (or None)")
        if self.quantum is not None and self.quantum <= 0:
            raise ValueError("quantum must be positive (or None)")
        if self.slo_ms is not None and not self.slo_ms > 0:
            raise ValueError("slo_ms must be positive (or None)")
        if not 0.0 <= self.shed_budget <= 1.0:
            raise ValueError("shed_budget must be within [0, 1]")
        if self.shed_rate is not None and not self.shed_rate > 0:
            raise ValueError("shed_rate must be positive (or None)")
        if self.edf_boost < 0:
            raise ValueError("edf_boost must be non-negative")


def coerce_admission(admission) -> AdmissionConfig:
    """Normalize a policy name or config object into an AdmissionConfig.

    Args:
        admission: an :class:`AdmissionConfig`, a declarative spec with a
            ``to_config()`` method (:class:`repro.api.spec.AdmissionSpec`),
            a policy-name string (``"fifo"`` / ``"wfq"``), or ``None`` for
            the default config.

    Returns:
        The equivalent :class:`AdmissionConfig`.
    """
    if admission is None:
        return AdmissionConfig()
    if isinstance(admission, AdmissionConfig):
        return admission
    if hasattr(admission, "to_config"):     # AdmissionSpec, duck-typed to
        return admission.to_config()        # keep core free of api imports
    return AdmissionConfig(policy=str(admission).lower())


class _TenantQueue:
    """Per-tenant flow state for the DRR scan (entries in submit order)."""

    __slots__ = ("key", "weight", "deficit", "entries")

    def __init__(self, key: str, weight: float):
        self.key = key
        self.weight = weight
        self.deficit = 0.0
        self.entries: list = []


class _FusionGroup:
    """Staged fusion-eligible launches sharing one fuse key."""

    __slots__ = ("key", "members", "t_first")

    def __init__(self, key, t_first: float):
        self.key = key
        self.members: list = []
        self.t_first = t_first


class AdmissionController:
    """Queueing discipline between ``submit`` and the per-unit workers.

    Owns the set of admitted launches and decides, per idle unit, which
    launch's scheduler gets to emit the next package. The caller (engine
    or simulator) serializes all calls and remains responsible for
    executing packages and finalizing launches.

    Attributes:
        config: the immutable :class:`AdmissionConfig` in force.
        dispatched: packages handed out over the controller's lifetime.
        fused_batches: fused launches materialized so far.
        fused_members: total members coalesced into those batches.
        offered: launches offered through :meth:`offer` so far.
        shed_count: offered launches rejected by the shed estimator.
        decision_log: ``("accept" | "shed", tenant)`` per offered launch,
            in offer order — the structural surface the real-vs-sim
            trace-replay parity tests compare.
        fusion_log: one tuple of member tenants per materialized fused
            batch, in materialization order.
    """

    def __init__(self, num_units: int,
                 config: Optional[AdmissionConfig] = None, *,
                 fuse_materialize: Optional[Callable] = None,
                 speed_refresh: Optional[Callable] = None,
                 on_activate: Optional[Callable] = None):
        """Build a controller.

        Args:
            num_units: Coexecution Unit count (bounds the DRR scan).
            config: admission configuration; default is plain FIFO.
            fuse_materialize: callback ``(members) -> fused_entry`` that
                coalesces ≥2 staged launches into one schedulable entry;
                when ``None``, staged groups are admitted member-by-member.
            speed_refresh: optional per-entry hook invoked right before
                pulling a package (the engine refreshes HGuided speeds).
            on_activate: optional hook invoked with each entry as it
                becomes schedulable (the execution loop strips dead-unit
                scheduler reservations here in elastic-cluster mode).
        """
        self.num_units = int(num_units)
        self.config = config or AdmissionConfig()
        self._fuse_materialize = fuse_materialize
        self._speed_refresh = speed_refresh
        self._on_activate = on_activate
        self._active: list = []     # FIFO admit order; guarded-by: caller
        self._tenants: dict[str, _TenantQueue] = {}  # guarded-by: caller
        self._ring: list[str] = []  # DRR service order; guarded-by: caller
        self._rr = 0  # guarded-by: caller
        self._staged: dict = {}     # fuse_key -> group; guarded-by: caller
        self._in_flight = 0  # guarded-by: caller
        self._auto_quantum = 1  # guarded-by: caller
        self.dispatched = 0
        self.fused_batches = 0
        self.fused_members = 0
        self.offered = 0
        self.shed_count = 0
        self._vfinish = 0.0  # shed estimator's virtual finish; guarded-by: caller
        self.decision_log: list[tuple[str, str]] = []
        self.fusion_log: list[tuple[str, ...]] = []

    # -- capacity ----------------------------------------------------------
    @property
    def in_flight(self) -> int:
        """Admitted-but-unfinished launches (fused members count singly)."""
        return self._in_flight

    def has_capacity(self) -> bool:
        """Whether one more launch may be admitted under ``max_inflight``."""
        cap = self.config.max_inflight
        return cap is None or self._in_flight < cap

    def drained(self) -> bool:
        """True when no admitted or staged work remains anywhere."""
        return not self._active and not self._staged

    def active_entries(self) -> list:
        """Schedulable entries in admit order (staged members excluded)."""
        return list(self._active)

    # -- admission ---------------------------------------------------------
    def offer(self, entry, now: float = 0.0) -> bool:
        """Accept-or-shed decision for one arriving launch (logged).

        Runs the deadline shed estimator: a virtual single server of
        capacity ``shed_rate`` items/s serves accepted launches in offer
        order; a launch whose estimated finish misses its ``deadline``
        is shed, as long as doing so keeps the shed fraction within
        ``shed_budget`` of everything offered so far (past the budget
        the launch is admitted late instead — graceful degradation).
        The verdict depends only on the arrival sequence, each entry's
        ``scheduler.total``/``deadline`` and the config — never on the
        execution substrate — so a trace replayed through the real
        engine and the DES produces the *same* accept/shed sequence.

        The caller still calls :meth:`admit` for accepted entries (or
        :meth:`~repro.core.exec.ExecutionLoop.offer`, which does both).

        Args:
            entry: launch-like object (``scheduler``/``tenant``; an
                optional ``deadline`` attribute holds its absolute
                deadline in the caller's clock).
            now: the entry's arrival time on that same clock.

        Returns:
            ``True`` to admit, ``False`` when the launch was shed.
        """
        self.offered += 1
        cfg = self.config
        deadline = getattr(entry, "deadline", None)
        finish = None
        if cfg.shed_rate is not None:
            start = max(self._vfinish, float(now))
            finish = start + entry.scheduler.total / cfg.shed_rate
        if (cfg.shed and finish is not None and deadline is not None
                and finish > deadline
                and self.shed_count + 1 <= cfg.shed_budget * self.offered):
            self.shed_count += 1
            self.decision_log.append(("shed", entry.tenant))
            return False
        if finish is not None:
            self._vfinish = finish
        self.decision_log.append(("accept", entry.tenant))
        return True

    def admit(self, entry, now: float = 0.0) -> None:
        """Admit one launch: activate it, or stage it for fusion.

        Args:
            entry: launch-like object (``scheduler``/``tenant``/``weight``,
                optional ``fuse_key``). Capacity is *not* checked here —
                callers gate on :meth:`has_capacity` first (the engine
                blocks or raises :class:`AdmissionFull` before admitting).
            now: current time (wall for the engine, virtual for the DES),
                used to timestamp fusion groups.

        Raises:
            ValueError: on a non-positive tenant weight.
        """
        if not float(entry.weight) > 0:
            raise ValueError(f"tenant weight must be positive, "
                             f"got {entry.weight!r}")
        self._in_flight += getattr(entry, "slots", 1)
        key = getattr(entry, "fuse_key", None)
        if self.config.fuse and key is not None:
            group = self._staged.get(key)
            if group is None:
                group = self._staged[key] = _FusionGroup(key, now)
            group.members.append(entry)
            if len(group.members) >= self.config.fuse_limit:
                self._flush_group(key)
            return
        self._activate(entry)

    def _activate(self, entry) -> None:
        """Make an entry schedulable (joins its tenant's DRR flow)."""
        self._active.append(entry)
        if self._on_activate is not None:
            self._on_activate(entry)
        # wfq_cost_scale converts an entry's package sizes to work-items
        # (engine-side fused batches schedule in member units, each worth
        # one member's whole index space of credit)
        scale = getattr(entry, "wfq_cost_scale", 1)
        self._auto_quantum = max(self._auto_quantum,
                                 entry.scheduler.quantum_hint() * scale)
        tq = self._tenants.get(entry.tenant)
        if tq is None:
            tq = self._tenants[entry.tenant] = _TenantQueue(
                entry.tenant, float(entry.weight))
            self._ring.append(entry.tenant)
        tq.weight = float(entry.weight)       # latest submission wins
        tq.entries.append(entry)

    def discard(self, entry) -> None:
        """Forget a finalized/failed entry and free its capacity slots.

        Args:
            entry: the launch previously admitted (or a fused entry
                produced by the materializer, which frees all its
                members' slots at once).
        """
        self._in_flight -= getattr(entry, "slots", 1)
        if entry in self._active:
            self._active.remove(entry)
        tq = self._tenants.get(getattr(entry, "tenant", None))
        if tq is not None and entry in tq.entries:
            tq.entries.remove(entry)
            if not tq.entries:      # classic DRR: credit dies with the flow
                del self._tenants[tq.key]
                self._ring.remove(tq.key)

    # -- fusion staging ----------------------------------------------------
    def pending_fusion(self) -> int:
        """Staged members still waiting in their batching window."""
        return sum(len(g.members) for g in self._staged.values())

    def next_ripen_in(self, now: float) -> Optional[float]:
        """Seconds until the oldest staged group ripens (None if empty)."""
        if not self._staged:
            return None
        t_first = min(g.t_first for g in self._staged.values())
        return max(0.0, self.config.fuse_wait_s - (now - t_first))

    def flush(self, now: float = 0.0, force: bool = False) -> None:
        """Materialize every staged group whose batching window elapsed.

        Args:
            now: current time, compared against each group's first-member
                timestamp.
            force: flush regardless of ripeness (engine shutdown, or the
                simulator once no further submissions can arrive).
        """
        for key in list(self._staged):
            group = self._staged[key]
            if (force or len(group.members) >= self.config.fuse_limit
                    or now - group.t_first >= self.config.fuse_wait_s):
                self._flush_group(key)

    def _flush_group(self, key) -> None:
        """Turn one staged group into schedulable entries."""
        group = self._staged.pop(key)
        if len(group.members) < 2 or self._fuse_materialize is None:
            for m in group.members:
                self._activate(m)
            return
        fused = self._fuse_materialize(group.members)
        fused.slots = sum(getattr(m, "slots", 1) for m in group.members)
        self.fused_batches += 1
        self.fused_members += len(group.members)
        self.fusion_log.append(tuple(m.tenant for m in group.members))
        self._activate(fused)

    # -- package selection -------------------------------------------------
    def next_work(self, unit: int) -> Optional[tuple[object, Package]]:
        """Pick the next package for an idle unit under the active policy.

        Args:
            unit: index of the idle Coexecution Unit.

        Returns:
            ``(entry, package)`` for the launch whose turn it is, or
            ``None`` when no admitted launch can serve this unit right now
            (drained schedulers, staged-only work, or per-unit exhaustion
            such as a static share already served).
        """
        if self.config.policy == "wfq":
            return self._next_wfq(unit)
        if self.config.policy == "edf":
            return self._next_edf(unit)
        return self._next_fifo(unit)

    def _pull(self, entry, unit: int,
              max_items: Optional[int] = None) -> Optional[Package]:
        """Ask one entry's scheduler for a package (with speed refresh)."""
        if getattr(entry, "failed", False):
            return None
        if self._speed_refresh is not None:
            self._speed_refresh(entry)
        if max_items is None:
            return entry.scheduler.next_package(unit)
        return entry.scheduler.next_package(unit, max_items=max_items)

    def _next_fifo(self, unit: int) -> Optional[tuple[object, Package]]:
        """PR 1 semantics: first admitted launch with a package wins."""
        for entry in self._active:
            pkg = self._pull(entry, unit)
            if pkg is not None:
                self.dispatched += 1
                return entry, pkg
        return None

    def _quantum(self) -> int:
        """DRR credit per round: configured, or the largest package hint."""
        return self.config.quantum or self._auto_quantum

    def _next_wfq(self, unit: int) -> Optional[tuple[object, Package]]:
        """Deficit-round-robin scan over tenant flows.

        A flow with credit serves one package and pays its size (credit
        may go briefly negative — surplus round robin — so schedulers
        keep full control of package sizing). When a full pass finds only
        credit-starved flows, the scan *fast-forwards* them the minimum
        number of whole rounds (``weight * quantum`` each) that puts the
        closest flow back in credit — equivalent to running those empty
        DRR rounds one by one, so service per tenant converges to the
        weight ratio while flows stay backlogged (the 2:1 fairness
        property the tests pin) for any weight or quantum scale, and
        ``None`` is returned only when no flow can serve this unit at
        all.

        With ``config.preempt`` the scan additionally caps each pull at
        the flow's remaining credit (in the entry's scheduler units via
        ``wfq_cost_scale``): a tenant whose scheduler wants to emit a
        giant package is preempted mid-launch down to what its credit
        covers, so overdraft is bounded by one granularity-aligned chunk
        instead of one whole package — the short-horizon fairness the
        preemption tests and benchmarks measure.
        """
        n = len(self._ring)
        if n == 0:
            return None
        while True:
            starved: list[_TenantQueue] = []
            for _ in range(n):
                tq = self._tenants[self._ring[self._rr % n]]
                if not tq.entries:
                    self._rr += 1
                    continue
                if tq.deficit <= 0.0:
                    starved.append(tq)
                    self._rr += 1
                    continue
                got = None
                for entry in tq.entries:
                    cap = None
                    if self.config.preempt:
                        scale = max(getattr(entry, "wfq_cost_scale", 1), 1)
                        cap = max(1, int(tq.deficit // scale))
                    pkg = self._pull(entry, unit, cap)
                    if pkg is not None:
                        got = (entry, pkg)
                        break
                if got is None:     # nothing for *this* unit in this flow
                    self._rr += 1
                    continue
                tq.deficit -= got[1].size * getattr(got[0], "wfq_cost_scale",
                                                    1)
                if tq.deficit <= 0.0:
                    self._rr += 1
                self.dispatched += 1
                return got
            if not starved:
                return None
            # fast-forward the empty rounds: every starved flow earns
            # whole rounds of credit until the closest one goes positive
            # (each pass retires at least one flow from `starved`, so
            # this terminates within len(ring) passes).
            q = self._quantum()
            k = min(math.floor(-tq.deficit / (tq.weight * q)) + 1
                    for tq in starved)
            for tq in starved:
                tq.deficit += k * tq.weight * q

    def _flow_deadline(self, tq: _TenantQueue) -> float:
        """A flow's urgency: earliest member deadline (inf when unset)."""
        return min((e.deadline for e in tq.entries
                    if getattr(e, "deadline", None) is not None),
                   default=math.inf)

    def _next_edf(self, unit: int) -> Optional[tuple[object, Package]]:
        """Earliest-deadline-first DRR scan with deadline-rank boosts.

        WFQ's credit machinery (including preemptive pull-capping) with
        two deadline-aware twists, both deterministic functions of the
        admitted set — no clock reads, so both substrates decide alike:

        * the serve scan visits flows earliest-absolute-deadline-first
          (deadline-free flows last, in stable ring order) instead of
          round-robin, so an urgent tenant with credit is always served
          before a relaxed one;
        * the starved-flow fast-forward refill grants credit at an
          *effective* weight ``weight * (1 + edf_boost / (rank + 1))``
          where rank orders starved flows by deadline — the flows
          nearest their deadline come back into credit sooner and
          therefore accumulate service faster while the pressure lasts.

        Boosted credit is quantized to whole quanta (``round`` of the
        effective weight, floored at one) so deficits stay multiples of
        the package-sized quantum: fractional credit would make the
        preemptive pull cap shave remainder-sized slivers off packages,
        multiplying per-package host overhead under load.
        """
        if not self._ring:
            return None
        while True:
            ranked = sorted(
                (tq for tq in (self._tenants[key] for key in self._ring)
                 if tq.entries),
                key=lambda tq: (self._flow_deadline(tq),
                                self._ring.index(tq.key)))
            if not ranked:
                return None
            starved: list[_TenantQueue] = []
            for tq in ranked:
                if tq.deficit <= 0.0:
                    starved.append(tq)
                    continue
                got = None
                for entry in tq.entries:
                    cap = None
                    if self.config.preempt:
                        scale = max(getattr(entry, "wfq_cost_scale", 1), 1)
                        cap = max(1, int(tq.deficit // scale))
                    pkg = self._pull(entry, unit, cap)
                    if pkg is not None:
                        got = (entry, pkg)
                        break
                if got is None:     # nothing for *this* unit in this flow
                    continue
                tq.deficit -= got[1].size * getattr(got[0], "wfq_cost_scale",
                                                    1)
                self.dispatched += 1
                return got
            if not starved:
                return None
            # deadline-rank boosted fast-forward: starved flows earn whole
            # rounds of credit at their boosted effective weight until the
            # closest one goes positive (same termination argument as the
            # WFQ refill — each pass retires at least one flow).
            q = self._quantum()
            boost = self.config.edf_boost
            by_deadline = sorted(starved,
                                 key=lambda tq: (self._flow_deadline(tq),
                                                 self._ring.index(tq.key)))
            eff = {id(tq): max(1.0, round(tq.weight *
                                          (1.0 + boost / (rank + 1))))
                   for rank, tq in enumerate(by_deadline)}
            k = min(math.floor(-tq.deficit / (eff[id(tq)] * q)) + 1
                    for tq in starved)
            for tq in starved:
                tq.deficit += k * eff[id(tq)] * q


def service_fairness_curve(service: Sequence[tuple[float, str, int]],
                           tenants: Sequence[str], *,
                           samples: int = 9) -> list[float]:
    """Jain fairness of cumulative per-tenant service at sampled horizons.

    The *fairness curve* preemption is judged on: at each of ``samples``
    evenly spaced horizons across the service timeline, take Jain's index
    over how many work-items each tenant has completed so far. Bursty
    service (one tenant receiving a giant package while others wait)
    shows up as a sagging curve even when end-to-end latencies come out
    equal; preemptive pull-capping lifts it.

    Args:
        service: ``(t_complete, tenant, items)`` per dispatched package,
            as produced by both execution backends (any monotone measure
            works for ``t_complete`` — virtual seconds, wall seconds, or
            a dispatch index).
        tenants: the tenant population (tenants with no service yet
            count as zero allocations — that is the point).
        samples: number of evenly spaced horizons to sample.

    Returns:
        One Jain index per horizon, in time order (empty-service
        horizons report 1.0 — nobody is ahead).

    Raises:
        ValueError: if ``tenants`` is empty.
    """
    if not tenants:
        raise ValueError("service_fairness_curve needs at least one tenant")
    events = sorted(service)
    if not events:
        return [1.0] * samples
    t_end = events[-1][0]
    served = {t: 0 for t in tenants}
    curve: list[float] = []
    idx = 0
    for k in range(1, samples + 1):
        horizon = t_end * k / (samples + 1)
        while idx < len(events) and events[idx][0] <= horizon:
            _, tenant, items = events[idx]
            if tenant in served:
                served[tenant] += items
            idx += 1
        total = sum(served.values())
        curve.append(jain_index(list(served.values())) if total else 1.0)
    return curve


def jain_index(allocations: Sequence[float]) -> float:
    """Jain's fairness index over per-tenant allocations.

    Args:
        allocations: one non-negative service measure per tenant
            (items/second, completed items, 1/latency, ...).

    Returns:
        A value in ``(0, 1]``; 1.0 means perfectly equal allocations,
        ``1/n`` means one tenant got everything.

    Raises:
        ValueError: if ``allocations`` is empty.
    """
    xs = [float(x) for x in allocations]
    if not xs:
        raise ValueError("jain_index of empty sequence")
    s = sum(xs)
    s2 = sum(x * x for x in xs)
    return (s * s) / (len(xs) * s2) if s2 > 0 else 1.0
