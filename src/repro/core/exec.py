"""Shared co-execution control plane: one loop, two backends.

The paper's central claim is that one kernel and one load-balancing
policy should run unchanged across heterogeneous devices. Before this
module, the repo violated its own version of that principle: the real
engine (:mod:`repro.core.engine`, worker threads + JAX dispatch) and the
discrete-event simulator (:mod:`repro.core.sim`, virtual clock) each
reimplemented the full Commander control loop — admission pulls,
scheduler refresh, launch-fusion staging and de-mux, finalization, and
dispatch/H2D/D2H counter attribution — so every policy had to be written
twice and parity-tested by hand.

:class:`ExecutionLoop` is the single implementation of that control
plane. A :class:`Backend` supplies only the execution substrate:

* **how time flows** — :meth:`Backend.now` is the wall clock for the
  engine's ``RealBackend`` and the virtual clock for the simulator's
  ``SimBackend``;
* **how a package runs** — :meth:`Backend.dispatch` either executes it
  through the data plane on a :class:`~repro.core.units.JaxUnit` or
  models its cost on a :class:`~repro.core.units.SimUnit`;
* **how the driver parks** — :meth:`Backend.wait_next_event` blocks a
  worker thread (real) or advances the event queue (sim);
* **how fused payloads materialize and results land** — the remaining
  hooks (:meth:`Backend.fuse_payload`, :meth:`Backend.deliver`, ...).

Everything policy-shaped — which launch an idle unit serves (FIFO/WFQ
via the :class:`~repro.core.admission.AdmissionController`, including
preemptive pull-capping), when staged fusion groups ripen, how a fused
batch de-multiplexes to its members, when a launch finalizes, and how
data-plane counters are attributed (remainder-distributed integer shares
for fused members) — is decided *here, once*, so a new policy is a
one-place change that both substrates inherit structurally.
"""
from __future__ import annotations

import abc
import collections
import dataclasses
import itertools
from typing import Optional, Sequence

from .admission import AdmissionConfig, AdmissionController
from .dataplane import DataPlaneCounters
from .package import Package, Range, validate_cover
from .scheduler import Scheduler

__all__ = ["Backend", "ExecutionLoop", "LaunchState", "LaunchStats"]


@dataclasses.dataclass
class LaunchStats:
    """Per-launch metrics mirroring the paper's measurements.

    Produced by the shared :class:`ExecutionLoop` for *both* backends, so
    real-vs-sim counter parity is structural rather than test-enforced.
    Isolated per launch: concurrent launches on the same units each get
    their own instance (busy seconds derive from this launch's packages
    only, never from cumulative unit counters). For a launch served
    through a fused batch, ``packages`` holds one synthesized package
    covering the launch's whole index space, timed by the shared dispatch
    that computed it, and ``data`` is the member's remainder-distributed
    integer share of the batch's counters — summing member stats recovers
    the batch's real copy/dispatch totals exactly.

    ``data`` carries the launch's data-plane accounting — dispatches and
    explicit H2D/D2H staging copies/bytes — so the USM-vs-BUFFERS
    distinction of the configured :class:`~.memory.MemoryModel` is
    observable per launch (USM performs zero staging copies).
    """

    total_s: float
    packages: list[Package]
    unit_busy_s: dict[str, float]
    data: DataPlaneCounters = dataclasses.field(
        default_factory=DataPlaneCounters)

    @property
    def num_packages(self) -> int:
        """Number of packages this launch was served as."""
        return len(self.packages)


class LaunchState:
    """Control-plane state of one in-flight co-execution (both backends).

    Backends subclass this with their payload — the real engine adds the
    kernel/arrays/handle, the simulator adds the modeled workload — but
    every field the :class:`ExecutionLoop` reads or writes lives here,
    which is what lets one loop implementation schedule both substrates.

    ``wfq_cost_scale`` converts scheduler units to work-items for WFQ
    credit (an engine-side fused batch schedules in members, each worth a
    whole member index space); ``member_span`` is the inverse axis: how
    many scheduler units one fused member occupies (1 for the engine's
    member-unit schedulers, the per-member item count for the
    simulator's item-unit schedulers).
    """

    __slots__ = ("id", "scheduler", "tenant", "weight", "t_submit",
                 "deadline", "fuse_key", "fuse_bucket", "slots", "members",
                 "member_span", "wfq_cost_scale", "done_pkgs", "outstanding",
                 "pending_reissue", "failed", "finalized", "fused", "stats")

    def __init__(self, launch_id: int, scheduler: Scheduler, *,
                 tenant: Optional[str] = None, weight: float = 1.0,
                 t_submit: float = 0.0):
        self.id = launch_id
        self.scheduler = scheduler
        self.tenant = tenant if tenant is not None else f"launch-{launch_id}"
        self.weight = float(weight)
        self.t_submit = t_submit
        self.deadline: Optional[float] = None   # absolute, backend clock
        self.fuse_key = None
        self.fuse_bucket: Optional[int] = None  # pad size under fuse_buckets
        self.slots = 1
        self.members: Optional[list["LaunchState"]] = None
        self.member_span = 1
        self.wfq_cost_scale = 1
        self.done_pkgs: list[Package] = []
        self.outstanding = 0          # issued but not yet collected
        self.pending_reissue = 0      # ranges queued for re-issue (unit loss)
        self.failed = False
        self.finalized = False
        self.fused = False            # served through a coalesced batch
        self.stats: Optional[LaunchStats] = None


class Backend(abc.ABC):
    """Execution substrate driven by the shared :class:`ExecutionLoop`.

    The three abstract methods are the whole substrate contract —
    wall-clock threads (``RealBackend``) and the virtual-clock DES
    (``SimBackend``) differ *only* here plus the payload hooks below.
    The loop sets :attr:`loop` to itself at construction so hooks can
    reach shared helpers (e.g. :meth:`ExecutionLoop.member_spans`).
    """

    loop: "ExecutionLoop" = None

    @abc.abstractmethod
    def now(self) -> float:
        """Current time: wall seconds (real) or virtual seconds (sim)."""

    @abc.abstractmethod
    def dispatch(self, unit: int, launch: LaunchState, pkg: Package) -> None:
        """Run or model one package on ``unit``.

        Args:
            unit: index of the Coexecution Unit serving the package.
            launch: the owning launch (payload fields are backend-typed).
            pkg: the package to execute; the backend fills its
                ``t_complete``/``t_collected`` timestamps (``t_issue`` is
                stamped by :meth:`ExecutionLoop.pull`).
        """

    @abc.abstractmethod
    def wait_next_event(self) -> None:
        """Park until more work may exist (thread wait / event advance)."""

    # -- payload hooks (overridden per substrate) ---------------------------
    def fuse_payload(self, members: list[LaunchState],
                     launch_id: int) -> LaunchState:
        """Materialize the backend payload of a fused batch.

        Args:
            members: ≥2 staged fusion-eligible launches (same fuse key).
            launch_id: id the loop assigned the fused entry.

        Returns:
            A fresh :class:`LaunchState` whose scheduler covers the
            members' combined index space; tenant/weight/slots are
            filled in by the loop afterwards.
        """
        raise NotImplementedError("this backend does not support fusion")

    def launch_counters(self, launch: LaunchState) -> DataPlaneCounters:
        """Snapshot one launch's data-plane accounting."""
        return DataPlaneCounters()

    def commit_member(self, fused: LaunchState, member: LaunchState,
                      index: int, cover: Package) -> None:
        """Land one fused member's output (engine: copy its row out)."""

    def deliver(self, launch: LaunchState) -> None:
        """Hand a finalized launch (stats populated) to the caller."""

    def fail(self, launch: LaunchState, err: BaseException) -> None:
        """Surface a launch failure (engine: resolve the handle future).

        Args:
            launch: the failing launch — for a fused batch the loop calls
                this once per member, never for the synthetic batch entry.
            err: the package error or cover-validation failure.
        """
        raise err

    def refresh_speeds(self, launch: LaunchState) -> None:
        """Feed measured throughput into an adaptive launch's scheduler."""

    def on_package(self, launch: LaunchState, pkg: Package) -> None:
        """Observe one collected package (sim: service-curve sampling)."""

    def package_lost(self, launch: LaunchState, pkg: Package) -> None:
        """Roll back substrate accounting of a package lost to unit death.

        Called by :meth:`ExecutionLoop.unit_lost` for every in-flight
        package the dead unit owned, *before* its range is queued for
        re-issue. A backend that charged counters or modeled cost at
        dispatch time undoes that here so the disturbed run's accounting
        equals an undisturbed one (the lost attempt never happened as far
        as the data plane is concerned). Default: nothing was charged yet.
        """


class ExecutionLoop:
    """The one Commander loop both backends drive.

    Owns the :class:`~repro.core.admission.AdmissionController` and every
    control-plane decision between ``submit`` and launch completion. The
    caller serializes all calls (the engine under its condition variable,
    the simulator single-threaded) exactly as with the controller itself.
    """

    def __init__(self, backend: Backend, unit_names: Sequence[str],
                 config: Optional[AdmissionConfig] = None, *,
                 validate: bool = True):
        """Build the loop over a backend and its named units.

        Args:
            backend: the execution substrate (real or simulated).
            unit_names: one display name per Coexecution Unit — the keys
                of every ``LaunchStats.unit_busy_s`` the loop produces.
            config: admission configuration; default is plain FIFO.
            validate: assert each launch's packages exactly tile its
                index space at finalization.
        """
        self.backend = backend
        backend.loop = self
        self.unit_names = list(unit_names)
        self.validate = validate
        self._ids = itertools.count()
        # Elastic-cluster state: which unit indices are currently dead, a
        # per-unit ownership ledger of in-flight packages keyed by
        # (launch id, package seq), and the queue of ranges harvested from
        # dead units awaiting exact re-issue to survivors. A pipelined
        # unit (pipeline_depth >= 2) holds several entries here at once —
        # one per pulled-but-uncompleted package, in issue order — and
        # unit_lost disowns *all* of them, so a unit that dies with a
        # full pipeline re-issues every in-flight range exactly once.
        self.dead_units: set[int] = set()
        self._owned: dict[int, dict[tuple[int, int],
                                    tuple[LaunchState, Package]]] = {}
        self._reissue: collections.deque[tuple[LaunchState, Range]] = \
            collections.deque()
        self.reissued = 0             # packages re-emitted after unit loss
        self.admission = AdmissionController(
            len(self.unit_names), config,
            fuse_materialize=self._materialize_fused,
            speed_refresh=backend.refresh_speeds,
            on_activate=self._scrub_dead_units)

    # -- identity / capacity -----------------------------------------------
    def next_id(self) -> int:
        """A fresh launch id (shared across plain and fused launches)."""
        return next(self._ids)

    def drained(self) -> bool:
        """True when no admitted or staged work remains anywhere."""
        return self.admission.drained()

    # -- admission ---------------------------------------------------------
    def admit(self, launch: LaunchState, now: Optional[float] = None) -> None:
        """Admit one launch: activate it, or stage it for fusion.

        Args:
            launch: the launch to admit; capacity is the caller's concern
                (the engine gates on ``max_inflight`` before admitting).
            now: admission time; defaults to the backend clock.
        """
        self.admission.admit(launch, self.backend.now() if now is None
                             else now)

    def offer(self, launch: LaunchState, now: Optional[float] = None) -> bool:
        """Offer one arriving launch: shed it, or admit it (logged).

        The open-loop entry point both substrates use for timed traffic:
        assigns the config's default SLO deadline when the launch has
        none, asks the admission controller's deadline shed estimator
        for a verdict, and admits on acceptance. The decision depends
        only on the arrival sequence and the config (see
        :meth:`~repro.core.admission.AdmissionController.offer`), which
        is what makes replayed accept/shed sequences identical across
        the real engine and the DES.

        Args:
            launch: the arriving launch; its ``deadline`` (absolute, on
                this backend's clock) may already be set by the caller.
            now: arrival time; defaults to the backend clock.

        Returns:
            ``True`` when the launch was admitted, ``False`` when shed
            (the caller surfaces the rejection — the engine resolves the
            handle with :class:`~repro.core.admission.LaunchShed`).
        """
        t = self.backend.now() if now is None else now
        cfg = self.admission.config
        if launch.deadline is None and cfg.slo_ms is not None:
            launch.deadline = t + cfg.slo_ms / 1e3
        if not self.admission.offer(launch, t):
            return False
        self.admission.admit(launch, t)
        return True

    # -- package flow ------------------------------------------------------
    def pull(self, unit: int, *, now: Optional[float] = None,
             force_flush: bool = False
             ) -> Optional[tuple[LaunchState, Package]]:
        """Pick the next package for an idle unit under the active policy.

        Flushes ripened fusion groups first, then asks the admission
        controller whose turn it is. The returned package is stamped with
        ``t_issue`` and counted as outstanding on its launch.

        Args:
            unit: index of the idle Coexecution Unit.
            now: current time; defaults to the backend clock.
            force_flush: materialize staged fusion groups regardless of
                window ripeness (engine shutdown; simulator once no
                further submissions can arrive).

        Returns:
            ``(launch, package)``, or ``None`` when nothing can serve
            this unit right now.
        """
        if unit in self.dead_units:
            return None
        t = self.backend.now() if now is None else now
        self.admission.flush(t, force=force_flush)
        # Recovery work jumps the queue: a re-issued range was already
        # admitted and WFQ-charged at its original issue, so serving it
        # first keeps fairness attribution exact and clears the backlog a
        # dead unit left behind before new packages are cut.
        while self._reissue:
            launch, rng = self._reissue.popleft()
            launch.pending_reissue -= 1
            if launch.failed or launch.finalized:
                continue
            pkg = launch.scheduler.reissue(rng, unit)
            launch.outstanding += 1
            pkg.t_issue = t
            self._owned.setdefault(unit, {})[(launch.id, pkg.seq)] = \
                (launch, pkg)
            self.admission.dispatched += 1
            self.reissued += 1
            return launch, pkg
        got = self.admission.next_work(unit)
        if got is not None:
            launch, pkg = got
            launch.outstanding += 1
            pkg.t_issue = t
            self._owned.setdefault(unit, {})[(launch.id, pkg.seq)] = \
                (launch, pkg)
        return got

    def complete(self, launch: LaunchState, pkg: Package,
                 error: Optional[BaseException] = None) -> None:
        """Record one served package; finalize the launch when drained.

        Args:
            launch: the package's launch.
            pkg: the package the backend just executed/modeled.
            error: the package's failure, if it had one — fails the whole
                launch (first error wins).

        A package whose issuing unit died since the pull was *disowned*
        by :meth:`unit_lost` (its range is already queued for re-issue);
        a late completion from such a zombie worker is dropped here so
        the work-item is never counted twice.
        """
        owned = self._owned.get(pkg.unit)
        key = (launch.id, pkg.seq)
        if owned is None or key not in owned:
            return      # disowned: the unit died, the range was re-issued
        del owned[key]
        launch.outstanding -= 1
        if error is not None:
            self.fail(launch, error)
            return
        if launch.failed:
            return      # a sibling package already failed the launch
        launch.done_pkgs.append(pkg)
        self.backend.on_package(launch, pkg)
        if (launch.scheduler.done() and launch.outstanding == 0
                and launch.pending_reissue == 0):
            self._finalize(launch)

    def fail(self, launch: LaunchState, err: BaseException) -> None:
        """Abort a launch on its first error (idempotent).

        Args:
            launch: the launch (or fused batch) that failed.
            err: the error to surface through the backend, once per
                member for a fused batch.
        """
        if launch.failed or launch.finalized:
            return
        launch.failed = True
        launch.finalized = True
        self.admission.discard(launch)
        for target in (launch.members if launch.members is not None
                       else [launch]):
            self.backend.fail(target, err)

    # -- elastic membership ------------------------------------------------
    def in_flight_of(self, unit: int) -> int:
        """Number of issued-but-uncollected packages a unit currently owns.

        Bounded by the engine's ``pipeline_depth``: a serial unit owns at
        most one package between pull and complete, a pipelined worker
        keeps up to ``depth`` staged/computing/collecting at once.
        """
        return len(self._owned.get(unit, ()))

    def oldest_issue(self, unit: int) -> Optional[float]:
        """Issue time of the unit's longest-outstanding package (or None).

        The supervisor's straggler detector compares this age against the
        pool's typical package service time.
        """
        owned = self._owned.get(unit)
        if not owned:
            return None
        return min(p.t_issue for _, p in owned.values())

    def unit_lost(self, unit: int) -> int:
        """Declare one unit dead and queue its work for exact re-issue.

        Idempotent per death. Two kinds of work migrate to survivors:

        * **in-flight packages** the unit pulled but never completed —
          each is disowned (a zombie completion is dropped by
          :meth:`complete`), rolled back through
          :meth:`Backend.package_lost`, and its exact :class:`Range`
          queued for re-emission;
        * **reserved un-issued work** a partitioned scheduler set aside
          for this unit (a static region, work-stealing chunks) —
          harvested via :meth:`~repro.core.scheduler.Scheduler.unit_lost`
          from every active launch so nothing strands on a dead unit.

        Because a re-issued range is bitwise the same interval, survivors
        recompute exactly the lost work-items: the finished launch is
        bitwise-identical to an undisturbed run and per-launch counters
        balance exactly (the lost attempt is uncounted, the re-issue
        recounted).

        Args:
            unit: index of the dead Coexecution Unit.

        Returns:
            Number of ranges queued for re-issue by this call.
        """
        if unit in self.dead_units:
            return 0
        self.dead_units.add(unit)
        moved = 0
        for launch, pkg in self._owned.pop(unit, {}).values():
            launch.outstanding -= 1
            if launch.failed or launch.finalized:
                continue    # nothing to recover for an aborted launch
            self.backend.package_lost(launch, pkg)
            self.admission.dispatched -= 1
            launch.pending_reissue += 1
            self._reissue.append((launch, Range(pkg.offset, pkg.size)))
            moved += 1
        for entry in self.admission.active_entries():
            moved += self._harvest_reserved(entry, unit)
        return moved

    def unit_joined(self, unit: int, *, name: Optional[str] = None,
                    speed: Optional[float] = None) -> None:
        """Bring a unit (back) into the pool.

        A known index is a revival — the dormant/dead unit simply starts
        pulling again (its statically reserved regions were given away at
        loss time; adaptive policies serve it naturally). An index one
        past the end grows the pool, and every active launch's scheduler
        is notified so per-unit structures exist before the first pull.

        Args:
            unit: index of the joining Coexecution Unit.
            name: display name for a brand-new unit.
            speed: relative throughput hint for adaptive schedulers.
        """
        if unit < len(self.unit_names):
            self.dead_units.discard(unit)
            return
        if unit != len(self.unit_names):
            raise ValueError(f"unit {unit} would leave a gap in the pool "
                             f"(size {len(self.unit_names)})")
        self.unit_names.append(name or f"unit{unit}")
        self.admission.num_units = len(self.unit_names)
        for entry in self.admission.active_entries():
            hook = getattr(entry.scheduler, "unit_joined", None)
            if hook is not None:
                hook(unit, speed=speed)

    def _harvest_reserved(self, entry: LaunchState, unit: int) -> int:
        """Queue one launch's dead-unit scheduler reservations for re-issue."""
        hook = getattr(entry.scheduler, "unit_lost", None)
        if hook is None or entry.failed or entry.finalized:
            return 0
        moved = 0
        for rng in hook(unit):
            entry.pending_reissue += 1
            self._reissue.append((entry, rng))
            moved += 1
        return moved

    def _scrub_dead_units(self, entry: LaunchState) -> None:
        """Strip dead-unit reservations from a newly activated launch.

        A launch admitted (or a fusion group materialized) while part of
        the pool is dead carries scheduler regions no one will ever pull;
        they move straight to the re-issue queue so the launch cannot
        wedge waiting on a unit that is not coming back.
        """
        for unit in self.dead_units:
            self._harvest_reserved(entry, unit)

    # -- fusion ------------------------------------------------------------
    def _materialize_fused(self, members: list[LaunchState]) -> LaunchState:
        """Coalesce staged member launches into one schedulable entry.

        The backend builds the payload (the engine stacks inputs and
        vmaps the kernel; the simulator concatenates workloads); the
        shared bookkeeping — id, tenant flow, combined weight, earliest
        submit time — happens here so both substrates agree on how a
        fused batch participates in admission.
        """
        fused = self.backend.fuse_payload(list(members), self.next_id())
        fused.tenant = f"fused-{fused.id}"
        fused.weight = sum(m.weight for m in members)
        fused.t_submit = min(m.t_submit for m in members)
        # EDF urgency of a batch is its most urgent member's deadline
        fused.deadline = min((m.deadline for m in members
                              if m.deadline is not None), default=None)
        fused.members = list(members)
        for m in members:
            m.fused = True
        return fused

    @staticmethod
    def member_spans(launch: LaunchState, pkg: Package):
        """Attribute one fused package's work to the members it covers.

        Args:
            launch: a fused batch entry (``members`` is not ``None``).
            pkg: one of its dispatched packages.

        Yields:
            ``(member, items)`` pairs — real work-items of each member
            this package computed (used for tenant service curves).
        """
        span = launch.member_span
        scale = launch.wfq_cost_scale
        first = pkg.offset // span
        last = -(-(pkg.offset + pkg.size) // span)
        for mi in range(first, last):
            lo = max(pkg.offset, mi * span)
            hi = min(pkg.offset + pkg.size, (mi + 1) * span)
            if hi > lo:
                yield launch.members[mi], (hi - lo) * scale

    # -- finalization ------------------------------------------------------
    def _busy_of(self, pkgs: Sequence[Package]) -> dict[str, float]:
        """Per-unit busy seconds derived from one launch's packages only."""
        busy = {name: 0.0 for name in self.unit_names}
        for p in pkgs:
            busy[self.unit_names[p.unit]] += max(p.t_complete - p.t_issue,
                                                 0.0)
        return busy

    def _finalize(self, launch: LaunchState) -> None:
        """Resolve a launch whose last package was collected."""
        if launch.finalized:
            return
        launch.finalized = True
        self.admission.discard(launch)
        # The launch ends when its last package is collected — taken from
        # the package timeline, not the backend clock: on the sim backend
        # the clock still reads the final package's *issue* time here
        # (its modeled cost has not advanced the event queue yet), and
        # the timeline is what both backends stamp identically.
        end = max((p.t_collected for p in launch.done_pkgs),
                  default=self.backend.now())
        if self.validate:
            try:
                validate_cover(launch.done_pkgs, launch.scheduler.total)
            except BaseException as e:
                launch.failed = True
                for target in (launch.members if launch.members is not None
                               else [launch]):
                    self.backend.fail(target, e)
                return
        if launch.members is not None:
            self._demux_fused(launch, end)
            return
        launch.stats = LaunchStats(
            total_s=end - launch.t_submit,
            packages=list(launch.done_pkgs),
            unit_busy_s=self._busy_of(launch.done_pkgs),
            data=self.backend.launch_counters(launch))
        self.backend.deliver(launch)

    def _demux_fused(self, fused: LaunchState, end: float) -> None:
        """Scatter a completed fused batch back to its member launches.

        Each member gets its output committed through the backend and a
        synthesized single-package stats record timed by the shared
        dispatch that computed it. The batch's data-plane accounting is
        attributed in remainder-distributed integer shares
        (:meth:`~repro.core.dataplane.DataPlaneCounters.split`), so
        summing member stats recovers the batch's real copy/dispatch
        totals exactly even when ``counters % members != 0``.
        """
        pkgs = sorted(fused.done_pkgs, key=lambda p: p.offset)
        shares = self.backend.launch_counters(fused).split(len(fused.members))
        span = fused.member_span
        for i, m in enumerate(fused.members):
            start = i * span
            cover = next(p for p in pkgs
                         if p.offset <= start < p.offset + p.size)
            mp = Package(rng=Range(0, m.scheduler.total), seq=0,
                         unit=cover.unit)
            mp.t_issue, mp.t_launch = cover.t_issue, cover.t_launch
            mp.t_complete, mp.t_collected = cover.t_complete, cover.t_collected
            busy = {name: 0.0 for name in self.unit_names}
            members_in_cover = max(cover.size // span, 1)
            busy[self.unit_names[cover.unit]] = max(
                cover.t_complete - cover.t_issue, 0.0) / members_in_cover
            self.backend.commit_member(fused, m, i, cover)
            m.finalized = True
            m.stats = LaunchStats(total_s=end - m.t_submit, packages=[mp],
                                  unit_busy_s=busy, data=shares[i])
            self.backend.deliver(m)
