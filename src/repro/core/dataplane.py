"""Typed kernel protocol + the real engine's data plane (paper §3.1).

The paper's headline observation is that co-execution gets *cheaper* under
unified shared memory: with USM every Coexecution Unit reads from and
writes into one logical allocation, so result collection is a no-op
(Fig. 2b), whereas per-package Buffers pay an explicit staging copy in and
a copy-back out for every package. Until this module, that distinction
lived only in the DES cost model — the real engine always staged the same
way and merely *labelled* launches USM or BUFFERS.

Two pieces make the distinction real:

* **`CoexecKernel`** — the typed kernel ABI. A kernel declares its
  per-argument partition semantics instead of being a positional closure:
  each argument is either ``SPLIT`` (sliced along a declared axis by the
  package range, optionally with a zero-filled ``halo`` for stencils) or
  ``BROADCAST`` (every unit sees the whole array — MatMul's ``B`` operand,
  Ray's sphere scene), plus an output slot describing dtype and trailing
  shape. This is EngineCL's kernel/data API (arXiv:1805.02755) crossed
  with Celerity-style per-argument access semantics (arXiv:2505.06022):
  the runtime, not the kernel author, decides data movement.
* **Data planes** — one strategy object per
  :class:`~repro.core.memory.MemoryModel`, selected by the engine from its
  spec. :class:`UsmDataPlane` hands units zero-copy host views of the
  shared arrays and lands results directly in the shared output container;
  :class:`BuffersDataPlane` stages each package's slices with
  ``jax.device_put``, dispatches on the staged buffers, and copies results
  back through a per-package buffer before merging. Both are instrumented:
  every launch carries :class:`DataPlaneCounters` (dispatches, H2D/D2H
  staging copies and bytes) surfaced in
  :class:`~repro.core.engine.LaunchStats`, so ``MemorySpec`` finally
  selects observable behavior end-to-end.

On this CPU-only substrate "device memory" and host memory coincide, so
the USM plane's zero-copy claim is literal (numpy views over the shared
allocation) while the BUFFERS plane really performs the extra copies the
paper charges that model for.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .memory import MemoryModel

try:  # jax is always present in this repo, but keep the DES importable alone
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover
    jax = None
    jnp = None

__all__ = [
    "ArgRole", "ArgSpec", "OutputSpec", "CoexecKernel", "as_coexec_kernel",
    "DataPlaneCounters", "LaunchPlan", "DataPlane", "UsmDataPlane",
    "BuffersDataPlane", "make_plane",
]


class ArgRole(enum.Enum):
    """How the data plane moves one kernel argument (per-argument access)."""

    SPLIT = "split"
    BROADCAST = "broadcast"


@dataclasses.dataclass(frozen=True)
class ArgSpec:
    """Partition semantics of one kernel argument.

    Attributes:
        name: argument name (documentation + error messages).
        role: ``SPLIT`` — sliced to the package range along ``axis``;
            ``BROADCAST`` — the whole array reaches every unit.
        axis: the split axis (``SPLIT`` only).
        halo: extra items on both sides of a split slice, zero-filled
            outside the index space (stencil kernels; ``SPLIT`` only).
        default: zero-arg factory for an argument the caller may omit
            (``BROADCAST`` only — e.g. Ray's demo sphere scene).
    """

    name: str
    role: ArgRole = ArgRole.SPLIT
    axis: int = 0
    halo: int = 0
    default: Optional[Callable[[], np.ndarray]] = None

    def __post_init__(self) -> None:
        if self.halo < 0:
            raise ValueError(f"halo must be >= 0, got {self.halo}")
        if self.role is ArgRole.BROADCAST and self.halo:
            raise ValueError(f"arg {self.name!r}: halo is a SPLIT property")
        if self.role is ArgRole.SPLIT and self.default is not None:
            raise ValueError(
                f"arg {self.name!r}: defaults are for BROADCAST args "
                f"(split args define the index space)")


@dataclasses.dataclass(frozen=True)
class OutputSpec:
    """Output slot of a kernel: dtype + trailing shape past the index axis.

    Attributes:
        dtype: numpy dtype of the output container.
        trailing: trailing dims after the split axis — a literal tuple, or
            a callable ``fn(inputs) -> tuple`` for input-dependent shapes
            (MatMul's ``(B.shape[1],)``).
    """

    dtype: Any = np.float32
    trailing: Any = ()

    def trailing_shape(self, inputs: Sequence[np.ndarray]) -> tuple:
        """Resolve the trailing dims for concrete inputs.

        Args:
            inputs: the launch's (bound) input arrays.

        Returns:
            The trailing shape tuple.
        """
        if callable(self.trailing):
            return tuple(self.trailing(inputs))
        return tuple(self.trailing)


@dataclasses.dataclass(frozen=True)
class CoexecKernel:
    """A co-executable kernel: compute body + declared data semantics.

    The compute body keeps the paper's package signature
    ``fn(offset, *chunks) -> chunk_out`` (offset is the package's global
    start, for index-dependent kernels), but the *chunks* are now produced
    by the data plane according to :attr:`args` instead of being uniform
    axis-0 slices: split args arrive as package slices (plus halo),
    broadcast args arrive whole.

    Instances are hashable (the engine's jit cache and fusion coalescing
    key on them) and callable with the legacy package signature, so a
    ``CoexecKernel`` drops in anywhere a positional closure was accepted.
    """

    name: str
    fn: Callable
    args: tuple[ArgSpec, ...]
    out: OutputSpec = OutputSpec()

    @property
    def all_split(self) -> bool:
        """True when every arg is a plain axis-0 split with no halo."""
        return all(a.role is ArgRole.SPLIT and a.axis == 0 and a.halo == 0
                   for a in self.args)

    def bind(self, inputs: Sequence[np.ndarray]) -> list:
        """Fill omitted trailing defaults and return the full input list.

        Args:
            inputs: caller-supplied arrays, shortest-prefix order.

        Returns:
            One array per declared argument.

        Raises:
            ValueError: wrong argument count (missing args without a
                default, or extras).
        """
        bound = list(inputs)
        for spec in self.args[len(bound):]:
            if spec.default is None:
                raise ValueError(
                    f"kernel {self.name!r} takes {len(self.args)} args "
                    f"({', '.join(a.name for a in self.args)}); "
                    f"got {len(inputs)}")
            bound.append(np.asarray(spec.default()))
        if len(bound) > len(self.args):
            raise ValueError(
                f"kernel {self.name!r} takes {len(self.args)} args "
                f"({', '.join(a.name for a in self.args)}); "
                f"got {len(inputs)}")
        return bound

    def alloc_out(self, total: int,
                  inputs: Sequence[np.ndarray]) -> np.ndarray:
        """Allocate the host output container for a launch.

        Args:
            total: launch index-space size.
            inputs: the launch's input arrays (for input-dependent
                trailing shapes).

        Returns:
            A zeroed ``(total, *trailing)`` array of the declared dtype.
        """
        trailing = self.out.trailing_shape(self.bind(inputs))
        return np.zeros((total, *trailing), dtype=self.out.dtype)

    def __call__(self, offset, *chunks):
        """Legacy package-signature call: ``kernel(offset, *chunks)``."""
        filled = list(chunks)
        for spec in self.args[len(filled):]:
            if spec.default is None:
                break
            filled.append(np.asarray(spec.default()))
        return self.fn(offset, *filled)


def as_coexec_kernel(fn: Callable, num_args: int) -> CoexecKernel:
    """Wrap a positional package closure in the typed protocol.

    The compatibility adapter for pre-protocol kernels: every argument is
    treated as a plain axis-0 split, which is exactly what the engine did
    for all inputs before per-argument semantics existed.

    Args:
        fn: legacy package kernel ``fn(offset, *chunks) -> chunk_out``.
        num_args: how many input arrays the kernel takes.

    Returns:
        An equivalent :class:`CoexecKernel` with all-``SPLIT`` args.
    """
    if isinstance(fn, CoexecKernel):
        return fn
    args = tuple(ArgSpec(f"arg{i}") for i in range(num_args))
    return CoexecKernel(getattr(fn, "__name__", "kernel"), fn, args)


# ---------------------------------------------------------------------------
# Instrumentation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DataPlaneCounters:
    """Copy/dispatch accounting of one launch (or one simulated run).

    Attributes:
        dispatches: package executions issued to the units.
        h2d_copies: explicit host→device staging copies (``device_put``
            of a package slice or broadcast operand). Zero under USM.
        h2d_bytes: bytes moved by those staging copies.
        d2h_copies: explicit device→host copy-backs through a per-package
            buffer before the merge. Zero under USM (results land in the
            shared container directly).
        d2h_bytes: bytes moved by those copy-backs.
    """

    dispatches: int = 0
    h2d_copies: int = 0
    h2d_bytes: int = 0
    d2h_copies: int = 0
    d2h_bytes: int = 0

    @property
    def staging_copies(self) -> int:
        """Total explicit staging copies (H2D + D2H) this launch paid."""
        return self.h2d_copies + self.d2h_copies

    def snapshot(self) -> "DataPlaneCounters":
        """An independent copy (for freezing into launch stats)."""
        return dataclasses.replace(self)

    def split(self, n: int) -> list["DataPlaneCounters"]:
        """Divide these counters into ``n`` shares that sum to the whole.

        Used when a fused batch's shared accounting is attributed to its
        member launches: each member gets an even integer share (the
        division remainder lands on the first members), so summing
        member stats never overcounts the batch's real copies/dispatches.

        Args:
            n: number of shares (the fused member count).

        Returns:
            ``n`` counter objects whose fields sum to this object's.
        """
        shares = [DataPlaneCounters() for _ in range(n)]
        for field in dataclasses.fields(self):
            total = getattr(self, field.name)
            base, rem = divmod(int(total), n)
            for i, share in enumerate(shares):
                setattr(share, field.name, base + (1 if i < rem else 0))
        return shares

    def to_dict(self) -> dict:
        """Plain-dict form for JSON benchmark artifacts."""
        return dataclasses.asdict(self)


class LaunchPlan:
    """Per-launch data-plane state: bound kernel, arrays, counters.

    Built once per submit by :meth:`DataPlane.plan`; worker threads share
    it (counter updates are lock-protected, the arrays are only read and
    the output container is written in disjoint package ranges).
    """

    __slots__ = ("kernel", "inputs", "out", "total", "counters", "_lock")

    def __init__(self, kernel: CoexecKernel, inputs: list, out: np.ndarray,
                 total: int):
        self.kernel = kernel
        self.inputs = inputs
        self.out = out
        self.total = int(total)
        self.counters = DataPlaneCounters()
        self._lock = threading.Lock()

    def add(self, **deltas: int) -> None:
        """Atomically bump counter fields by the given deltas."""
        with self._lock:
            for key, delta in deltas.items():
                setattr(self.counters, key, getattr(self.counters, key)
                        + int(delta))


# ---------------------------------------------------------------------------
# Data planes
# ---------------------------------------------------------------------------

def _bucket(size: int) -> int:
    """Next power of two — bounds jit compilations to O(log total)."""
    b = 1
    while b < size:
        b <<= 1
    return b


def _split_view(arr: np.ndarray, spec: ArgSpec, offset: int, size: int,
                total: int) -> np.ndarray:
    """The package slice of one split arg, halo zero-filled at the edges."""
    lo = offset - spec.halo
    hi = offset + size + spec.halo
    lo_pad, hi_pad = max(0, -lo), max(0, hi - total)
    index = [slice(None)] * arr.ndim
    index[spec.axis] = slice(max(lo, 0), min(hi, total))
    view = arr[tuple(index)]
    if lo_pad or hi_pad:
        pad = [(0, 0)] * arr.ndim
        pad[spec.axis] = (lo_pad, hi_pad)
        view = np.pad(view, pad)
    return view


def _fill_split(buf: np.ndarray, arr: np.ndarray, spec: ArgSpec,
                offset: int, size: int, total: int) -> None:
    """Assemble one split chunk in place in a reused staging buffer.

    Writes exactly the values :func:`_package_chunks` would produce for
    the same package — interior slice, zero-filled halo at the edges,
    zero bucket pad — into ``buf`` (whose split-axis extent must already
    be ``size + 2*halo + grow``), so the BUFFERS plane's staged operands
    stay bitwise identical to the USM plane's without a fresh pad
    allocation per package.
    """
    lo = offset - spec.halo
    hi = offset + size + spec.halo
    lo_pad = max(0, -lo)
    index = [slice(None)] * arr.ndim
    index[spec.axis] = slice(max(lo, 0), min(hi, total))
    view = arr[tuple(index)]
    dst = [slice(None)] * buf.ndim
    dst[spec.axis] = slice(lo_pad, lo_pad + view.shape[spec.axis])
    buf.fill(0)
    buf[tuple(dst)] = view


def _package_chunks(plan: LaunchPlan, pkg):
    """Yield ``(spec, chunk)`` per argument for one package.

    Split args are sliced to the package range (plus halo) and
    zero-padded up to the package's power-of-two size bucket; broadcast
    args pass through whole. The bucket pad is applied identically by
    both data planes — it is compile-shape management (bounding XLA
    recompilation), not data movement, and keeping the shapes equal
    across planes is what makes USM-vs-BUFFERS results bitwise identical
    (the same executable runs on the same values).
    """
    grow = _bucket(pkg.size) - pkg.size
    for spec, arr in zip(plan.kernel.args, plan.inputs):
        if spec.role is ArgRole.SPLIT:
            chunk = _split_view(arr, spec, pkg.offset, pkg.size, plan.total)
            if grow:
                pad = [(0, 0)] * chunk.ndim
                pad[spec.axis] = (0, grow)
                chunk = np.pad(chunk, pad)
        else:
            chunk = arr
        yield spec, chunk


class DataPlane:
    """Data-movement strategy for one memory model (template class).

    Subclasses implement :meth:`_stage` (how package inputs reach the
    unit) and :meth:`_collect` (how the result lands in the launch's
    output container); :meth:`execute` runs the shared dispatch protocol
    and timestamps the package.
    """

    model: MemoryModel

    def plan(self, kernel: CoexecKernel, inputs: Sequence[np.ndarray],
             out: np.ndarray, total: int) -> LaunchPlan:
        """Bind a launch's arrays to the kernel's declared arguments.

        Args:
            kernel: the typed kernel being launched.
            inputs: caller-supplied input arrays (defaults are filled).
            out: host output container (written along axis 0).
            total: launch index-space size.

        Returns:
            The launch's :class:`LaunchPlan`.

        Raises:
            ValueError: wrong argument count, or a split argument whose
                extent along its axis does not match ``total``.
        """
        bound = kernel.bind(inputs)
        for spec, arr in zip(kernel.args, bound):
            if spec.role is not ArgRole.SPLIT:
                continue
            extent = int(np.asarray(arr).shape[spec.axis])
            if extent != total:
                raise ValueError(
                    f"kernel {kernel.name!r} arg {spec.name!r} is SPLIT "
                    f"along axis {spec.axis} with extent {extent}, but the "
                    f"launch index space is {total}")
        return LaunchPlan(kernel, bound, out, total)

    def execute(self, unit, plan: LaunchPlan, pkg) -> None:
        """Run one package end to end on `unit` and commit its output.

        The serial (``pipeline_depth=1``) composition of the three
        pipeline phases: :meth:`stage` the inputs, :meth:`issue` the
        kernel, :meth:`complete` the result. Sets ``pkg.t_launch`` /
        ``pkg.t_complete`` / ``pkg.t_collected`` and updates the plan's
        counters; the caller sets ``pkg.t_issue``.

        Args:
            unit: the :class:`~repro.core.units.JaxUnit` executing it.
            plan: the launch's data-plane state.
            pkg: the :class:`~repro.core.package.Package` to run.
        """
        args = self.stage(unit, plan, pkg)
        out_dev = self.issue(unit, plan, pkg, args)
        self.complete(unit, plan, pkg, out_dev)

    def stage(self, unit, plan: LaunchPlan, pkg) -> list:
        """Phase 1 — materialize the package's inputs for ``unit``.

        Pure host-side work (slicing, padding, ``device_put`` under
        BUFFERS); safe to run while an earlier package of the same unit
        is still computing on the device.

        Args:
            unit: the unit the package will run on.
            plan: the launch's data-plane state.
            pkg: the package whose inputs to materialize.

        Returns:
            The staged argument list for :meth:`issue`.
        """
        return self._stage(unit, plan, pkg)

    def issue(self, unit, plan: LaunchPlan, pkg, args: list):
        """Phase 2 — dispatch the kernel asynchronously on ``unit``.

        Stamps ``pkg.t_launch`` and counts the dispatch, but does *not*
        wait for the device: the returned handle is an un-materialized
        device value whose completion :meth:`complete` later awaits, so
        the caller may overlap further staging with the compute.

        Args:
            unit: the executing unit.
            plan: the launch's data-plane state.
            pkg: the package being dispatched.
            args: staged arguments from :meth:`stage`.

        Returns:
            The in-flight device output handle.
        """
        plan.add(dispatches=1)
        pkg.t_launch = time.perf_counter()
        return unit.dispatch(plan.kernel.fn, pkg.offset, args)

    def complete(self, unit, plan: LaunchPlan, pkg, out_dev, *,
                 busy_floor: float = 0.0) -> None:
        """Phase 3 — await the device, attribute busy time, land output.

        Blocks on the device completion event, charges the compute span
        to ``unit``, collects the result into the plan's output
        container and stamps ``pkg.t_collected``.

        Args:
            unit: the unit that ran the package.
            plan: the launch's data-plane state.
            pkg: the package to complete.
            out_dev: the in-flight handle from :meth:`issue`.
            busy_floor: completion time of the unit's previous package;
                with several packages in flight their launch→complete
                spans overlap, so busy time is charged from
                ``max(t_launch, busy_floor)`` to avoid double-counting
                the overlapped stretch. ``0.0`` (serial) charges the
                full launch→complete span, exactly as before the split.

        Raises:
            TypeError: ``out_dev`` has no ``block_until_ready`` — an
                unknown output type the async path cannot synchronize
                on (a silent no-sync here would hand :meth:`_collect`
                a result that may still be materializing).
        """
        sync = getattr(out_dev, "block_until_ready", None)
        if sync is None:
            raise TypeError(
                f"kernel {plan.kernel.name!r} returned "
                f"{type(out_dev).__name__!r}, which has no "
                f"block_until_ready; the pipelined data plane cannot "
                f"synchronize on it (kernels must return a jax array)")
        sync()
        pkg.t_complete = time.perf_counter()
        unit.add_busy(pkg.t_complete - max(pkg.t_launch, busy_floor))
        self._collect(plan, pkg, out_dev)
        pkg.t_collected = time.perf_counter()

    def prewarm(self, units: Sequence, plan: LaunchPlan,
                granularity: int) -> None:
        """Compile every package bucket on every unit before dispatch.

        Package slices are padded to power-of-two compile buckets (see
        :func:`_package_chunks`), so a launch over ``plan.total`` items
        can only ever present ``O(log total)`` distinct input shapes.
        Tracing + compiling each of them here, at plan-build time, keeps
        JIT compile time out of ``unit.add_busy`` — a bucket's first
        dispatch would otherwise charge the compile to the unit and
        poison the dynamic (hguided / work-stealing) speed estimates.
        Warm-up results are discarded; counters are not touched.

        Warm-up is best-effort: a kernel that fails to trace or compile
        is left for the real dispatch path, whose error handling fails
        the launch through its handle — pre-warming must not turn a
        launch failure into a submit-time exception.

        Args:
            units: the engine's units (each warms its own jit cache).
            plan: the launch whose kernel/input shapes to warm.
            granularity: package alignment — the smallest bucket is
                ``_bucket(granularity)``.
        """
        bucket = _bucket(max(int(granularity), 1))
        top = _bucket(plan.total)
        while True:
            args = []
            for spec, arr in zip(plan.kernel.args, plan.inputs):
                if spec.role is ArgRole.SPLIT:
                    shape = list(np.asarray(arr).shape)
                    shape[spec.axis] = bucket + 2 * spec.halo
                    args.append(np.zeros(tuple(shape),
                                         np.asarray(arr).dtype))
                else:
                    args.append(arr)
            for unit in units:
                try:
                    unit.prewarm(plan.kernel.fn, args)
                except Exception:
                    import logging
                    logging.getLogger(__name__).debug(
                        "pre-warm of kernel %r skipped; first dispatch "
                        "will compile (or fail through its handle)",
                        plan.kernel.name, exc_info=True)
                    return
            if bucket >= top:
                break
            bucket <<= 1

    # -- subclass hooks ----------------------------------------------------
    def _stage(self, unit, plan: LaunchPlan, pkg) -> list:
        raise NotImplementedError

    def _collect(self, plan: LaunchPlan, pkg, out_dev) -> None:
        raise NotImplementedError


class UsmDataPlane(DataPlane):
    """Unified-shared-memory data plane: zero staging copies.

    Every unit computes directly on host views of the shared input
    arrays (split args are numpy slices of the one allocation; broadcast
    args are passed whole), and the result is written straight into the
    launch's shared output container — the paper's "collection is free"
    USM semantics (Fig. 2b). No ``device_put``, no copy-back buffer:
    ``h2d_copies == d2h_copies == 0`` by construction. (Both planes pad
    split chunks to a power-of-two compile bucket — shape management
    shared with BUFFERS, see :func:`_package_chunks` — which is not
    data movement and is not counted.)
    """

    model = MemoryModel.USM

    def _stage(self, unit, plan: LaunchPlan, pkg) -> list:
        return [chunk for _, chunk in _package_chunks(plan, pkg)]

    def _collect(self, plan: LaunchPlan, pkg, out_dev) -> None:
        # in-place landing in the one shared allocation — the USM no-op
        # collection (no intermediate per-package buffer is materialized)
        plan.out[pkg.offset:pkg.offset + pkg.size] = out_dev[:pkg.size]


class BuffersDataPlane(DataPlane):
    """Per-package buffers data plane: explicit staging in, copy-back out.

    Each package's split slices (and its broadcast operands — buffers are
    per-package in this model, as in the paper's SYCL Buffers mode where
    accessors are re-created for every command group) are staged with
    ``jax.device_put`` to the unit's device; the result is copied back
    into a per-package host buffer and then merged into the output
    container. Every copy increments the plan's counters. Staged values
    are identical to the USM plane's chunks (same slice + halo + bucket
    pad as :func:`_package_chunks`, assembled in place), which is what
    makes USM-vs-BUFFERS results *bitwise* identical for a fixed package
    structure — the same executable runs on the same values; only the
    data movement differs.

    Split-argument staging goes through a per-unit scratch pool: the
    host buffer a package's slice is assembled in is keyed by
    ``(unit, shape, dtype)`` — one compile bucket, one allocation — and
    returned to the pool when the package collects, instead of a fresh
    pad allocation per ``device_put``. A package in flight holds its
    scratch exclusively, so pipelined staging of package *k+1* can never
    overwrite buffers package *k* is still computing on. The pool is
    reuse of *allocations*, not of data movement: every package still
    pays its per-argument H2D copy and per-package D2H copy-back, so the
    counters are unchanged.
    """

    model = MemoryModel.BUFFERS

    def __init__(self):
        # free scratch per (unit, shape, dtype); leased scratch per
        # in-flight (plan, package) until its collect returns it
        self._scratch: dict[tuple, list] = {}   # guarded-by: _pool_lock
        self._leases: dict[tuple, list] = {}    # guarded-by: _pool_lock
        self._pool_lock = threading.Lock()

    def _borrow(self, unit, shape: tuple, dtype) -> tuple:
        key = (id(unit), tuple(shape), np.dtype(dtype).str)
        with self._pool_lock:
            free = self._scratch.get(key)
            buf = free.pop() if free else None
        if buf is None:
            buf = np.empty(tuple(shape), dtype)
        return key, buf

    def _stage(self, unit, plan: LaunchPlan, pkg) -> list:
        grow = _bucket(pkg.size) - pkg.size
        args, lease = [], []
        for spec, arr in zip(plan.kernel.args, plan.inputs):
            if spec.role is ArgRole.SPLIT:
                shape = list(arr.shape)
                shape[spec.axis] = pkg.size + 2 * spec.halo + grow
                key, buf = self._borrow(unit, shape, arr.dtype)
                _fill_split(buf, arr, spec, pkg.offset, pkg.size,
                            plan.total)
                staged = jax.device_put(buf, unit.device)
                lease.append((key, buf))
            else:
                staged = jax.device_put(arr, unit.device)
                buf = arr
            plan.add(h2d_copies=1, h2d_bytes=np.asarray(buf).nbytes)
            args.append(staged)
        if lease:
            with self._pool_lock:
                self._leases[(id(plan), pkg.seq)] = lease
        return args

    def _collect(self, plan: LaunchPlan, pkg, out_dev) -> None:
        # copy-back through a separate per-package buffer, then merge
        host = np.asarray(out_dev)
        plan.add(d2h_copies=1, d2h_bytes=host.nbytes)
        plan.out[pkg.offset:pkg.offset + pkg.size] = host[:pkg.size]
        with self._pool_lock:
            for key, buf in self._leases.pop((id(plan), pkg.seq), ()):
                self._scratch.setdefault(key, []).append(buf)


_PLANES = {MemoryModel.USM: UsmDataPlane(),
           MemoryModel.BUFFERS: BuffersDataPlane()}


def make_plane(model: MemoryModel) -> DataPlane:
    """The data plane implementing one memory model.

    Args:
        model: USM or BUFFERS.

    Returns:
        The (stateless, shared) :class:`DataPlane` instance.

    Raises:
        KeyError: unknown memory model.
    """
    return _PLANES[model]
