"""Load-balancing algorithms of the Coexecutor Runtime (paper §3.2).

Three policies implemented exactly as defined in the paper and its
antecedents (Maat [15], EngineCL [16], HGuided [18]), plus a fourth from
the same dynamic-policy family the paper argues for:

* ``Static``        — one package per unit, sized proportionally to the
                      unit's relative computing speed. Minimal management;
                      cannot adapt.
* ``Dynamic``       — N equal packages, handed to units on demand as they
                      go idle. Adapts to irregularity; pays one host⇄device
                      round trip per package.
* ``HGuided``       — package size for unit *i* when ``rem`` items remain:
                      ``max(min_pkg, rem * speed_i / (K * sum(speeds)))``,
                      so packages start large (∝ speed) and shrink as the
                      execution progresses. Few synchronisation points,
                      near-1.0 balance, no per-benchmark tuning parameter.
* ``WorkStealing``  — per-unit deques seeded by the static split and chopped
                      into chunks; a unit drains its own deque and, when
                      empty, steals half the remainder of the most-loaded
                      victim. Adapts like Dynamic but without the central
                      remaining-work cursor every package request contends
                      on — the natural fit for the persistent engine, where
                      packages of many concurrent launches interleave.

All schedulers hand out contiguous ranges aligned to ``granularity`` (the
kernel's local work size / hardware vector width), except possibly the final
package which takes whatever remains.

Thread-safety: `next_package` is called under the Director's/engine's
per-launch lock (real runtime) or single-threaded (simulator); schedulers
themselves are not internally locked.
"""
from __future__ import annotations

import abc
import collections
import math
from typing import Optional, Sequence

from .package import Package, Range


def _align_up(x: int, g: int) -> int:
    return ((x + g - 1) // g) * g


def static_bounds(total: int, speeds: Sequence[float],
                  granularity: int = 1) -> list[int]:
    """Monotone, granularity-aligned region boundaries ∝ relative speed.

    Returns ``len(speeds) + 1`` cumulative boundaries with ``bounds[0] == 0``
    and ``bounds[-1] == total``: exact cover by construction (the tail unit
    absorbs any alignment remainder; a unit whose share rounds to zero gets
    an empty region). Shared by the Static and WorkStealing seeds.
    """
    tot_speed = sum(speeds)
    cum = 0.0
    bounds = [0]
    for s in list(speeds)[:-1]:
        cum += total * s / tot_speed
        b = _align_up(int(round(cum)), granularity)
        bounds.append(min(max(b, bounds[-1]), total))
    bounds.append(total)
    return bounds


class Scheduler(abc.ABC):
    """Base class: owns the remaining-work cursor and the package log."""

    name: str = "base"

    def __init__(self, total: int, num_units: int, *, granularity: int = 1):
        if total <= 0:
            raise ValueError("total work must be positive")
        if num_units <= 0:
            raise ValueError("need at least one Coexecution Unit")
        if granularity <= 0:
            raise ValueError("granularity must be positive")
        self.total = int(total)
        self.num_units = int(num_units)
        self.granularity = int(granularity)
        self._cursor = 0
        self._seq = 0
        self.issued: list[Package] = []

    @property
    def remaining(self) -> int:
        """Work-items not yet handed out."""
        return self.total - self._cursor

    def done(self) -> bool:
        """Whether the whole index space has been issued as packages."""
        return self._cursor >= self.total

    def quantum_hint(self) -> int:
        """Typical package size in work-items, for cross-launch policies.

        The admission layer's deficit-round-robin needs a credit quantum
        on the same scale as the packages this scheduler emits (too small
        and every pull overdrafts; too large and fairness goes coarse).
        Policies with a natural package size override this; the default is
        a fraction of the index space per unit.

        Returns:
            A positive package-size estimate, at least ``granularity``.
        """
        return max(self.granularity, self.total // max(1, 4 * self.num_units))

    def _cap_size(self, size: int, max_items: Optional[int]) -> int:
        """Apply a preemption cap: align *down* to granularity, floor g.

        The cap comes from WFQ credit reclamation
        (:class:`~.admission.AdmissionConfig` ``preempt``): a capped
        package must not exceed the tenant's remaining credit by more
        than one granularity-aligned chunk, so the cap rounds down
        (whereas uncapped sizing rounds up to stay aligned).
        """
        if max_items is None:
            return size
        cap = max(int(max_items), 1)
        if cap >= size:
            return size
        return max((cap // self.granularity) * self.granularity,
                   self.granularity)

    # -- policy hook ------------------------------------------------------
    @abc.abstractmethod
    def _package_size(self, unit: int) -> int:
        """Size of the next package for `unit`, given current remaining."""

    # -- public API (called by the Commander loop) -------------------------
    def next_package(self, unit: int,
                     max_items: Optional[int] = None) -> Optional[Package]:
        """Emit the next contiguous package for an idle unit.

        Args:
            unit: Coexecution Unit index requesting work.
            max_items: optional preemption cap — the admission layer's
                WFQ credit reclamation asks for at most this many items;
                the emitted package may exceed it only up to granularity
                alignment (never below one granularity chunk).

        Returns:
            A fresh :class:`~.package.Package`, or ``None`` when this
            scheduler has nothing (left) for that unit.
        """
        if self.done():
            return None
        size = self._package_size(unit)
        size = max(1, min(size, self.remaining))
        # align to granularity unless this is the tail; a preemption cap
        # aligns down instead so the pull stays within credit
        if size < self.remaining:
            size = min(_align_up(size, self.granularity), self.remaining)
        size = min(self._cap_size(size, max_items), self.remaining)
        pkg = Package(rng=Range(self._cursor, size), seq=self._seq, unit=unit)
        self._cursor += size
        self._seq += 1
        self.issued.append(pkg)
        return pkg

    # -- elastic-cluster hooks ---------------------------------------------
    def reissue(self, rng: Range, unit: int) -> Package:
        """Re-emit a previously issued range after its unit died.

        The range was already cut from the index space (the cursor moved
        when it was first issued), so this only mints a fresh package
        around the *same* interval for a surviving unit — which is what
        makes recovery bitwise-identical to an undisturbed run.

        Args:
            rng: the exact lost interval, as first issued.
            unit: the surviving Coexecution Unit taking the work over.
        """
        pkg = Package(rng=rng, seq=self._seq, unit=unit)
        self._seq += 1
        self.issued.append(pkg)
        return pkg

    def unit_lost(self, unit: int) -> list[Range]:
        """Release work reserved for a dead unit.

        Policies with per-unit reservations (static regions, work-stealing
        deques) override this to hand the un-issued remainder back as
        ranges the execution loop re-issues to survivors; cursor-based
        policies reserve nothing, so the default releases nothing.

        Args:
            unit: index of the dead Coexecution Unit.

        Returns:
            Ranges no longer servable by this scheduler itself (they are
            accounted as issued here; the loop re-emits them).
        """
        return []

    def unit_joined(self, unit: int, speed: Optional[float] = None) -> None:
        """Accommodate a unit joining (or growing) the pool.

        The base scheduler only tracks the unit count; policies with
        per-unit structures (speeds, regions, deques) extend them so the
        newcomer can pull immediately.

        Args:
            unit: index of the joining Coexecution Unit.
            speed: optional relative-throughput hint for the newcomer.
        """
        if unit >= self.num_units:
            self.num_units = unit + 1


class StaticScheduler(Scheduler):
    """One package per unit, split ∝ relative speed (paper's `Static`)."""

    name = "static"

    def __init__(self, total: int, num_units: int, *,
                 speeds: Optional[Sequence[float]] = None, granularity: int = 1):
        super().__init__(total, num_units, granularity=granularity)
        if speeds is None:
            speeds = [1.0] * num_units
        if len(speeds) != num_units:
            raise ValueError("speeds length must match num_units")
        if any(s <= 0 for s in speeds):
            raise ValueError("speeds must be positive")
        self.speeds = [float(s) for s in speeds]
        # Precompute the split from aligned cumulative boundaries: exact
        # cover by construction; a unit whose share rounds to zero simply
        # gets no package.
        bounds = static_bounds(total, self.speeds, granularity)
        self._sizes = [bounds[i + 1] - bounds[i] for i in range(num_units)]
        self._bounds = bounds
        # per-unit region cursor: uncapped serving emits the whole region
        # as one package (the paper's semantics); a preemption cap may
        # split it, in which case the remainder stays servable.
        self._next = [bounds[i] for i in range(num_units)]

    def _package_size(self, unit: int) -> int:  # pragma: no cover - unused
        return self._sizes[unit]

    def quantum_hint(self) -> int:
        """Largest static share — one package is one unit's whole region."""
        return max(max(self._sizes), self.granularity)

    def next_package(self, unit: int,
                     max_items: Optional[int] = None) -> Optional[Package]:
        """Serve unit `unit` (the rest of) its precomputed region.

        Args:
            unit: Coexecution Unit index requesting work.
            max_items: optional preemption cap (splits the region; the
                remainder is served by later pulls).

        Returns:
            The unit's static share as one package (or the next capped
            slice of it), or ``None`` once the unit's region is drained
            (including shares that rounded to zero).
        """
        # Unit i's region is [bounds[i], bounds[i+1]) — deterministic
        # placement, as the paper's static split fixes regions at
        # configure time.
        lo, hi = self._next[unit], self._bounds[unit + 1]
        if lo >= hi or self.done():
            return None     # drained, or share rounded away
        size = self._cap_size(hi - lo, max_items)
        size = min(size, hi - lo)
        pkg = Package(rng=Range(lo, size), seq=self._seq, unit=unit)
        self._next[unit] = lo + size
        self._seq += 1
        self._cursor += size
        self.issued.append(pkg)
        return pkg

    def unit_lost(self, unit: int) -> list[Range]:
        """Hand back the un-served remainder of the dead unit's region.

        The region is marked drained (cursor advanced) so the launch can
        still complete: the released range is re-issued by the execution
        loop to whichever survivor idles first — the one adaptation the
        paper's static policy ever makes.
        """
        if unit >= len(self._next):
            return []
        lo, hi = self._next[unit], self._bounds[unit + 1]
        if lo >= hi:
            return []
        self._next[unit] = hi
        self._cursor += hi - lo
        return [Range(lo, hi - lo)]

    def unit_joined(self, unit: int, speed: Optional[float] = None) -> None:
        """A late joiner gets an empty region — static splits are fixed."""
        super().unit_joined(unit, speed)
        while len(self._next) < self.num_units:
            self._next.append(self.total)
            self._bounds.append(self.total)
            self._sizes.append(0)
            self.speeds.append(float(speed) if speed and speed > 0 else
                               sum(self.speeds) / len(self.speeds))


class DynamicScheduler(Scheduler):
    """N equal packages served on demand (paper's `Dynamic`, Dyn5/Dyn200)."""

    name = "dynamic"

    def __init__(self, total: int, num_units: int, *, num_packages: int = 200,
                 granularity: int = 1):
        super().__init__(total, num_units, granularity=granularity)
        if num_packages <= 0:
            raise ValueError("num_packages must be positive")
        self.num_packages = int(num_packages)
        self._pkg_size = max(1, math.ceil(total / self.num_packages))

    def _package_size(self, unit: int) -> int:
        return self._pkg_size

    def quantum_hint(self) -> int:
        """The fixed equal-package size, granularity-aligned.

        Aligned up exactly as :meth:`next_package` aligns the emitted
        packages, so the WFQ credit quantum matches real package sizes —
        which is also what keeps the engine's member-unit fused
        schedulers and the DES's item-unit ones on the same credit scale.
        """
        return max(_align_up(self._pkg_size, self.granularity),
                   self.granularity)


class HGuidedScheduler(Scheduler):
    """Heterogeneous guided self-scheduling (paper's `HGuided`).

    size_i = max(min_package, remaining * speed_i / (K * sum(speeds)))

    `speeds` is the computational-power hint (the `dist` 0.35 in Listing 1
    translates to speeds [0.35, 0.65] for [CPU, GPU]). K (the divisor)
    defaults to 2 as in the reference implementation.
    """

    name = "hguided"

    def __init__(self, total: int, num_units: int, *,
                 speeds: Optional[Sequence[float]] = None,
                 divisor: float = 2.0,
                 min_package: int = 1,
                 granularity: int = 1):
        super().__init__(total, num_units, granularity=granularity)
        if speeds is None:
            speeds = [1.0] * num_units
        if len(speeds) != num_units:
            raise ValueError("speeds length must match num_units")
        if any(s <= 0 for s in speeds):
            raise ValueError("speeds must be positive")
        if divisor <= 0:
            raise ValueError("divisor must be positive")
        self.speeds = [float(s) for s in speeds]
        self.divisor = float(divisor)
        self.min_package = max(int(min_package), granularity)

    def _package_size(self, unit: int) -> int:
        share = self.remaining * self.speeds[unit] / (
            self.divisor * sum(self.speeds))
        return max(self.min_package, int(share))

    def update_speed(self, unit: int, speed: float) -> None:
        """Online speed refinement from the profiler (EWMA throughput)."""
        if speed > 0:
            self.speeds[unit] = float(speed)

    def unit_joined(self, unit: int, speed: Optional[float] = None) -> None:
        """Grant the newcomer a speed share (hetero's ``add_group`` move).

        With no hint it enters at the pool's mean speed, shrinking every
        incumbent's *relative* share proportionally — the same
        renormalizing grant :func:`repro.core.cluster.grant_share`
        models — and the guided sizing formula adapts from the next pull.
        """
        super().unit_joined(unit, speed)
        while len(self.speeds) < self.num_units:
            self.speeds.append(float(speed) if speed and speed > 0 else
                               sum(self.speeds) / len(self.speeds))


class WorkStealingScheduler(Scheduler):
    """Per-unit deques seeded by the static split; idle units steal.

    Seeding: unit *i*'s region ``[bounds[i], bounds[i+1])`` (∝ speed, same
    boundaries as `Static`) is chopped into granularity-aligned chunks of
    ``~region/chunks_per_unit`` items, queued oldest-first in its own deque.

    Serving: ``next_package(i)`` pops the front of deque *i*. When the deque
    is empty the unit steals **half the remainder** (by chunk count, from
    the far end, preserving the victim's locality) of the most-loaded
    victim. ``None`` is returned only when every deque is empty — a unit
    never retires while any work remains anywhere, which is the termination
    property the Commander loop relies on.

    Compared to `Dynamic`/`HGuided`, there is no central remaining-work
    cursor: units touch shared state only on the (rare) steal path, so many
    concurrent launches on a persistent engine do not serialize on one
    cursor per package request. The total package count is fixed at seed
    time (steals move chunks, never split them), making the package count
    identical between the real engine and the DES for a given problem.
    """

    name = "work_stealing"

    def __init__(self, total: int, num_units: int, *,
                 speeds: Optional[Sequence[float]] = None,
                 chunks_per_unit: int = 8,
                 chunk_items: Optional[int] = None,
                 granularity: int = 1):
        super().__init__(total, num_units, granularity=granularity)
        if speeds is None:
            speeds = [1.0] * num_units
        if len(speeds) != num_units:
            raise ValueError("speeds length must match num_units")
        if any(s <= 0 for s in speeds):
            raise ValueError("speeds must be positive")
        if chunks_per_unit <= 0:
            raise ValueError("chunks_per_unit must be positive")
        if chunk_items is not None and chunk_items <= 0:
            raise ValueError("chunk_items must be positive")
        self.speeds = [float(s) for s in speeds]
        self.steals = 0
        bounds = static_bounds(total, self.speeds, granularity)
        self._deques: list[collections.deque[Range]] = []
        self._load = [0] * num_units        # un-issued items per deque
        self._chunk_hint = granularity
        for i in range(num_units):
            lo, hi = bounds[i], bounds[i + 1]
            dq: collections.deque[Range] = collections.deque()
            if hi > lo:
                step = (chunk_items if chunk_items is not None
                        else max(1, math.ceil((hi - lo) / chunks_per_unit)))
                step = _align_up(step, granularity)
                self._chunk_hint = max(self._chunk_hint, step)
                for off in range(lo, hi, step):
                    dq.append(Range(off, min(step, hi - off)))
            self._deques.append(dq)
            self._load[i] = hi - lo

    def _package_size(self, unit: int) -> int:  # pragma: no cover - unused
        dq = self._deques[unit]
        return dq[0].size if dq else 0

    def quantum_hint(self) -> int:
        """The seed chunk size (steals move chunks, never resize them)."""
        return self._chunk_hint

    def _steal_into(self, unit: int) -> None:
        victim = max((j for j in range(self.num_units) if j != unit),
                     key=lambda j: self._load[j], default=None)
        if victim is None or self._load[victim] == 0:
            return
        vq = self._deques[victim]
        take = (len(vq) + 1) // 2
        stolen = [vq.pop() for _ in range(take)]
        moved = sum(r.size for r in stolen)
        self._load[victim] -= moved
        self._load[unit] += moved
        # re-reverse so the thief also serves its loot in ascending order
        self._deques[unit].extend(reversed(stolen))
        self.steals += 1

    def next_package(self, unit: int,
                     max_items: Optional[int] = None) -> Optional[Package]:
        """Pop the unit's next chunk, stealing first if its deque is dry.

        Args:
            unit: Coexecution Unit index requesting work.
            max_items: optional preemption cap — a larger front chunk is
                split, its remainder staying at the front of this unit's
                deque (locality preserved; only capped pulls ever split,
                so the uncapped package count stays seed-deterministic).

        Returns:
            The next chunk as a package, or ``None`` only when every
            deque in the system is empty.
        """
        dq = self._deques[unit]
        if not dq:
            self._steal_into(unit)
        if not dq:
            return None
        rng = dq.popleft()
        take = self._cap_size(rng.size, max_items)
        if take < rng.size:
            dq.appendleft(Range(rng.offset + take, rng.size - take))
            rng = Range(rng.offset, take)
        self._load[unit] -= rng.size
        pkg = Package(rng=rng, seq=self._seq, unit=unit)
        self._seq += 1
        self._cursor += rng.size
        self.issued.append(pkg)
        return pkg

    def unit_lost(self, unit: int) -> list[Range]:
        """Drain the dead unit's deque; its chunks go to the re-issue queue.

        Survivors can no longer steal from it (load drops to zero), and
        the released chunks keep their seed boundaries, so the total
        package count stays deterministic across the disturbance.
        """
        if unit >= len(self._deques):
            return []
        dq = self._deques[unit]
        freed = list(dq)
        dq.clear()
        moved = sum(r.size for r in freed)
        self._load[unit] = 0
        self._cursor += moved
        return freed

    def unit_joined(self, unit: int, speed: Optional[float] = None) -> None:
        """A late joiner starts empty and steals its first chunks."""
        super().unit_joined(unit, speed)
        while len(self._deques) < self.num_units:
            self._deques.append(collections.deque())
            self._load.append(0)
            self.speeds.append(float(speed) if speed and speed > 0 else
                               sum(self.speeds) / len(self.speeds))


# ---------------------------------------------------------------------------
# Registration with the repro.api plugin registry
# ---------------------------------------------------------------------------
# The built-in policies register by name like any third-party plugin would:
# the registry (not an if-chain here) is the single policy selection point,
# and each registration declares exactly the option fields its constructor
# accepts so misspelled options fail with a ValueError naming the key.

def _dyn_shorthand(key: str) -> Optional[dict]:
    """``dynN`` → Dynamic with N packages (``dyn5``/``dyn200`` of §5)."""
    if key.startswith("dyn") and key != "dynamic" and key[3:].isdigit():
        return {"num_packages": int(key[3:])}
    return None


def _register_builtin_policies() -> None:
    """Idempotently register the paper's four policies (import side)."""
    from repro.api.registry import register_scheduler

    register_scheduler("static", StaticScheduler, fields=("speeds",),
                       speed_hint=True, overwrite=True)
    register_scheduler("dynamic", DynamicScheduler,
                       fields=("num_packages",),
                       shorthand=_dyn_shorthand, overwrite=True)
    register_scheduler("hguided", HGuidedScheduler,
                       fields=("speeds", "divisor", "min_package"),
                       speed_hint=True, overwrite=True)
    register_scheduler("work_stealing", WorkStealingScheduler,
                       fields=("speeds", "chunks_per_unit", "chunk_items"),
                       speed_hint=True, overwrite=True)


_register_builtin_policies()

# policies whose constructor takes a `speeds` hint (the paper's dist(0.35)).
# Kept as a constant for backward compatibility; the registry is the source
# of truth (repro.api.speed_hint_policies()).
SPEED_HINT_POLICIES = ("static", "hguided", "work_stealing")
