"""Quickstart: the paper's Listing 1 — SAXPY co-executed across all local
Coexecution Units with the HGuided balancer, configured declaratively
through `repro.api.CoexecSpec` (the spec serializes to JSON, so the whole
setup is a reproducible artifact).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import CoexecSpec
from repro.core import CoexecutorRuntime


def main() -> None:
    n = 1 << 20
    data = np.arange(n, dtype=np.float32)
    datav = 3.0

    # Listing 1, declaratively: policy <hg>, CounitSet, dist(0.35), usm
    spec = (CoexecSpec.builder()
            .policy("hguided")                             # <hg>
            .dist(0.35)                                    # dist(0.35)
            .memory("usm")
            .build())
    runtime = CoexecutorRuntime.from_spec(spec)            # CounitSet:
    # (no .units(...) call = one Coexecution Unit per local jax device)

    def kernel(offset, chunk):                             # the lambda
        return chunk * datav

    out = runtime.launch(n, kernel, [data], granularity=128)
    np.testing.assert_allclose(out, data * datav)
    assert CoexecSpec.from_json(spec.to_json()) == spec    # lossless

    st = runtime.last_stats
    print(f"co-executed {n} work-items in {st.total_s * 1e3:.1f} ms "
          f"across {len(st.unit_busy_s)} unit(s), "
          f"{st.num_packages} packages")
    for name, busy in st.unit_busy_s.items():
        print(f"  {name}: busy {busy * 1e3:.1f} ms")
    runtime.shutdown()


if __name__ == "__main__":
    main()
