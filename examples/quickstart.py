"""Quickstart: the paper's Listing 1 — SAXPY co-executed across all local
Coexecution Units with the HGuided balancer.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CoexecutorRuntime, counits_from_devices


def main() -> None:
    n = 1 << 20
    data = np.arange(n, dtype=np.float32)
    datav = 3.0

    # Listing 1, line by line:
    runtime = CoexecutorRuntime(policy="hguided")          # <hg>
    runtime.config(units=counits_from_devices(),           # CounitSet
                   dist=0.35,                              # dist(0.35)
                   memory="usm")

    def kernel(offset, chunk):                             # the lambda
        return chunk * datav

    out = runtime.launch(n, kernel, [data], granularity=128)
    np.testing.assert_allclose(out, data * datav)

    st = runtime.last_stats
    print(f"co-executed {n} work-items in {st.total_s * 1e3:.1f} ms "
          f"across {len(st.unit_busy_s)} unit(s), "
          f"{st.num_packages} packages")
    for name, busy in st.unit_busy_s.items():
        print(f"  {name}: busy {busy * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
