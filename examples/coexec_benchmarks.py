"""Co-execute the paper's six benchmarks (real kernels, real threads) and
reproduce the scheduler comparison on this host's devices.

    PYTHONPATH=src python examples/coexec_benchmarks.py [--n 16384]
"""
import argparse
import time

import numpy as np

from repro.api import CoexecSpec
from repro.core import CoexecutorRuntime
from repro.kernels import demo_spheres, package_kernel


def inputs_for(name: str, n: int):
    rng = np.random.default_rng(0)
    if name == "taylor":
        return [rng.uniform(-2, 2, n).astype(np.float32)]
    if name == "mandelbrot":
        side = int(np.sqrt(n))
        re_ = np.linspace(-2.2, 0.8, side, dtype=np.float32)
        im = np.linspace(-1.4, 1.4, side, dtype=np.float32)
        cre, cim = np.meshgrid(re_, im)
        return [cre.ravel(), cim.ravel()]
    if name == "ray":
        dx, dy = rng.uniform(-.4, .4, (2, n)).astype(np.float32)
        dz = np.sqrt(np.maximum(1 - dx**2 - dy**2, .5)).astype(np.float32)
        return [dx, dy, dz]
    if name == "rap":
        L = 64
        return [rng.normal(size=(n, L)).astype(np.float32),
                rng.integers(0, L, size=n).astype(np.int32)]
    raise KeyError(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 14)
    args = ap.parse_args()

    base = (CoexecSpec.builder()
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.5, 0.5))
            .dist(0.5)
            .build())
    units = base.build_units()      # shared across policies (one jit cache)
    for name in ("taylor", "mandelbrot", "ray", "rap"):
        ins = inputs_for(name, args.n)
        total = len(ins[0])
        print(f"== {name} ({total} items)")
        for policy in ("static", "dyn16", "hguided", "work_stealing"):
            spec = base.replace(
                scheduler=base.scheduler.replace(policy=policy))
            rt = CoexecutorRuntime.from_spec(spec, units=units)
            t0 = time.perf_counter()
            rt.launch(total, package_kernel(name), ins)
            dt = time.perf_counter() - t0
            print(f"   {policy:8s}: {dt * 1e3:7.1f} ms, "
                  f"{rt.last_stats.num_packages:3d} packages")


if __name__ == "__main__":
    main()
