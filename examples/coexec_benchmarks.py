"""Co-execute the paper's benchmarks (real kernels, real threads) and
reproduce the scheduler comparison on this host's devices.

Every kernel is resolved through the plugin registry
(`repro.api.build_kernel`) and declares its own data semantics — split
arrays, broadcast operands, stencil halos — so the one loop below drives
all of them with no per-kernel glue; `--memory buffers` switches the
engine's data plane and the printed staging-copy counters show the cost.

    PYTHONPATH=src python examples/coexec_benchmarks.py [--n 16384]
"""
import argparse
import time

from repro.api import CoexecSpec, build_kernel, kernel_demo_inputs
from repro.core import CoexecutorRuntime


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 14)
    ap.add_argument("--memory", choices=("usm", "buffers"), default="usm")
    args = ap.parse_args()

    base = (CoexecSpec.builder()
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.5, 0.5))
            .dist(0.5)
            .memory(args.memory)
            .build())
    units = base.build_units()      # shared across policies (one jit cache)
    for name in ("taylor", "mandelbrot", "ray", "rap"):
        kernel = build_kernel(name)
        ins = kernel_demo_inputs(name, args.n)
        print(f"== {name} ({args.n} items, {args.memory})")
        for policy in ("static", "dyn16", "hguided", "work_stealing"):
            spec = base.replace(
                scheduler=base.scheduler.replace(policy=policy))
            rt = CoexecutorRuntime.from_spec(spec, units=units)
            t0 = time.perf_counter()
            rt.launch(args.n, kernel, ins)
            dt = time.perf_counter() - t0
            st = rt.last_stats
            print(f"   {policy:8s}: {dt * 1e3:7.1f} ms, "
                  f"{st.num_packages:3d} packages, "
                  f"copies h2d={st.data.h2d_copies} "
                  f"d2h={st.data.d2h_copies}")


if __name__ == "__main__":
    main()
