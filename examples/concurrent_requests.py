"""Serve many co-execution requests concurrently on one persistent engine.

Demonstrates the engine lifecycle (start / submit / shutdown) and the
serving-shaped API: independent callers fire `launch_async` against the
same CoexecutorRuntime and their packages interleave on the shared
Coexecution Units — no per-launch thread spawn, per-launch isolated stats.
The whole setup is one declarative `CoexecSpec` built fluently; swap the
policy or admission discipline from the command line without touching the
engine code.

    PYTHONPATH=src python examples/concurrent_requests.py [--requests 12]
"""
import argparse
import threading
import time

import numpy as np

from repro.api import CoexecSpec
from repro.core import CoexecutorRuntime


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--n", type=int, default=1 << 15)
    ap.add_argument("--policy", default="work_stealing")
    args = ap.parse_args()

    spec = (CoexecSpec.builder()
            .policy(args.policy)
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.4, 0.6))
            .dist(0.4)
            .workload("taylor", items=args.n, requests=args.requests)
            .build())
    kernel = spec.build_kernel()        # resolved via the kernel registry
    rng = np.random.default_rng(0)
    xs = [rng.uniform(-2, 2, args.n).astype(np.float32)
          for _ in range(args.requests)]

    with CoexecutorRuntime.from_spec(spec) as rt:
        rt.launch(args.n, kernel, [xs[0]])          # warm the jit cache

        # many independent "callers" submit without blocking each other
        results = [None] * args.requests

        def caller(i: int) -> None:
            handle = rt.launch_async(args.n, kernel, [xs[i]])
            results[i] = (handle.result(), handle.stats)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=caller, args=(i,))
                   for i in range(args.requests)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0

        for i, (out, stats) in enumerate(results):
            np.testing.assert_allclose(out, np.sin(xs[i]),
                                       rtol=1e-3, atol=1e-4)
            print(f"request {i:2d}: {stats.num_packages:3d} packages, "
                  f"{stats.total_s * 1e3:6.1f} ms wall")
        print(f"\n{args.requests} concurrent requests on "
              f"{len(rt.engine.units)} units in {dt:.3f}s "
              f"({args.requests / dt:.1f} req/s), policy={rt.policy}")
        print("engine board:", rt.engine.board.snapshot())


if __name__ == "__main__":
    main()
