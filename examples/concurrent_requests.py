"""Serve many co-execution requests concurrently on one persistent engine.

Demonstrates the engine lifecycle (start / submit / shutdown) and the
serving-shaped API: independent callers fire `launch_async` against the
same CoexecutorRuntime and their packages interleave on the shared
Coexecution Units — no per-launch thread spawn, per-launch isolated stats.

    PYTHONPATH=src python examples/concurrent_requests.py [--requests 12]
"""
import argparse
import threading
import time

import numpy as np
import jax

from repro.core import CoexecutorRuntime, counits_from_devices
from repro.kernels import package_kernel


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--n", type=int, default=1 << 15)
    ap.add_argument("--policy", default="work_stealing")
    args = ap.parse_args()

    units = counits_from_devices(jax.local_devices()[:1] * 2,
                                 kinds=["cpu", "cpu"],
                                 speed_hints=[0.4, 0.6])
    kernel = package_kernel("taylor")
    rng = np.random.default_rng(0)
    xs = [rng.uniform(-2, 2, args.n).astype(np.float32)
          for _ in range(args.requests)]

    with CoexecutorRuntime(args.policy) as rt:
        rt.config(units=units, dist=0.4)
        rt.launch(args.n, kernel, [xs[0]])          # warm the jit cache

        # many independent "callers" submit without blocking each other
        results = [None] * args.requests

        def caller(i: int) -> None:
            handle = rt.launch_async(args.n, kernel, [xs[i]])
            results[i] = (handle.result(), handle.stats)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=caller, args=(i,))
                   for i in range(args.requests)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0

        for i, (out, stats) in enumerate(results):
            np.testing.assert_allclose(out, np.sin(xs[i]),
                                       rtol=1e-3, atol=1e-4)
            print(f"request {i:2d}: {stats.num_packages:3d} packages, "
                  f"{stats.total_s * 1e3:6.1f} ms wall")
        print(f"\n{args.requests} concurrent requests on "
              f"{len(units)} units in {dt:.3f}s "
              f"({args.requests / dt:.1f} req/s), policy={args.policy}")
        print("engine board:", rt.engine.board.snapshot())


if __name__ == "__main__":
    main()
