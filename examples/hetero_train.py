"""End-to-end driver: train an LM for a few hundred steps with
heterogeneity-aware data parallelism (the paper's co-execution applied to
SPMD training) + checkpointing + failure injection.

Reduced dims on this CPU container; at scale the same script drives pod
groups (`--arch` picks any of the 10 assigned architectures).

    PYTHONPATH=src python examples/hetero_train.py \
        --arch qwen3-0.6b --steps 200 --policy hguided
"""
import argparse
import tempfile

import jax

from repro.checkpoint import Checkpointer
from repro.configs import ARCH_IDS, get_config
from repro.data import DataPipeline
from repro.ft import FailurePlan, Supervisor
from repro.hetero import HeteroTrainer, make_policy
from repro.models import build_model, count_params
from repro.optim import AdamW, make_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--policy", default="hguided",
                    choices=["static", "dynamic", "hguided"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full published config (needs TPUs)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-crash-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{args.arch}: {count_params(params):,} params "
          f"({'full' if args.full_size else 'reduced'})")

    pipe = DataPipeline(seed=1, global_batch=args.microbatches,
                        seq_len=64 if not args.full_size else 4096,
                        vocab=cfg.vocab_size,
                        num_shards=args.microbatches)
    groups = {"podA": 1.0, "podB": 0.6, "podC": 0.3}
    lr = make_schedule(cfg.schedule, 3e-3, warmup=10, total=args.steps)
    trainer = HeteroTrainer(
        model, params, optimizer=AdamW(lr=lr),
        policy=make_policy(args.policy, {g: 1.0 for g in groups},
                           total_steps=args.steps),
        pipeline=pipe, group_speeds=groups,
        total_microbatches=args.microbatches)

    events = {}
    if args.inject_crash_at is not None:
        events[args.inject_crash_at] = "crash"
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="hetero_ckpt_")
    sup = Supervisor(trainer, Checkpointer(ckpt_dir), ckpt_every=25,
                     failure_plan=FailurePlan(events=events),
                     on_straggler=lambda g: print(f"  [straggler] {g}"))
    report = sup.run(args.steps)

    print(f"ran {report.steps_run} steps "
          f"({report.restarts} restarts, lost={report.groups_lost})")
    k = max(1, len(report.losses) // 10)
    for i in range(0, len(report.losses), k):
        r = trainer.history[min(i, len(trainer.history) - 1)]
        print(f"  step {i:4d}: loss={report.losses[i]:.4f} "
              f"assign={r.assignment} step_t={r.step_seconds * 1e3:.0f}ms")
    print(f"final loss: {report.losses[-1]:.4f}  "
          f"(checkpoints in {ckpt_dir})")


if __name__ == "__main__":
    main()
