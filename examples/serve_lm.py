"""Batched serving example: prefill + greedy decode with the KV cache,
reporting per-phase throughput. Works for every assigned arch (SSM/hybrid
archs use their O(1) recurrent state instead of a KV ring).

    PYTHONPATH=src python examples/serve_lm.py --arch h2o-danube3-4b \
        --batch 8 --prompt-len 64 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube3-4b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    B, P, G = args.batch, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    cache = model.init_cache(B, P + G)
    if model.prefill is not None:   # enc-dec: run the encoder once
        batch = {"tokens": prompts,
                 "frames": jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                     jnp.bfloat16)}
        cache = jax.jit(model.prefill)(params, batch, cache)
    step = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    for t in range(P):              # prefill via the cached decode path
        logits, cache = step(params, prompts[:, t:t + 1], cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
    out = [cur]
    t0 = time.perf_counter()
    for _ in range(G - 1):
        logits, cache = step(params, cur, cache)
        cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        out.append(cur)
    jax.block_until_ready(cur)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={args.arch} batch={B}")
    print(f"prefill: {B * P / t_prefill:8.0f} tok/s "
          f"({t_prefill * 1e3:.0f} ms for {B * P} tokens)")
    print(f"decode : {B * (G - 1) / t_decode:8.0f} tok/s "
          f"({t_decode * 1e3 / (G - 1):.1f} ms/step)")
    print(f"sample generation (row 0): {gen[0, :12].tolist()}")


if __name__ == "__main__":
    main()
