#!/usr/bin/env python3
"""Docs health check: mermaid blocks parse-sane, internal links resolve.

Stdlib-only (runs in CI's docs job and in tier-1 via tests/test_docs.py):

* every ```mermaid fence in README.md and docs/*.md must open with a
  known diagram type, balance its brackets, and contain at least one
  edge/message line;
* every relative markdown link must point at an existing file, and an
  in-page ``#anchor`` must match a real heading slug in the target.

Exit status 0 = clean; 1 = problems (one line each on stderr).
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

MERMAID_TYPES = ("graph", "flowchart", "sequenceDiagram", "stateDiagram",
                 "stateDiagram-v2", "classDiagram", "erDiagram", "gantt",
                 "pie", "mindmap", "timeline")
LINK_RE = re.compile(r"\[([^\]]+)\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def doc_files() -> list[pathlib.Path]:
    """README plus every markdown page under docs/."""
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    s = re.sub(r"[`*_]", "", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def mermaid_blocks(text: str) -> list[tuple[int, str]]:
    """(start_line, body) for each ```mermaid fence."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if lines[i].strip().startswith("```mermaid"):
            body, j = [], i + 1
            while j < len(lines) and not lines[j].strip().startswith("```"):
                body.append(lines[j])
                j += 1
            blocks.append((i + 1, "\n".join(body)))
            i = j
        i += 1
    return blocks


def strip_labels(line: str) -> str:
    """Remove quoted mermaid label text before bracket balancing."""
    return re.sub(r'"[^"]*"', '""', line)


def check_mermaid(path: pathlib.Path, errors: list[str]) -> None:
    """Validate every mermaid fence in one file."""
    for lineno, body in mermaid_blocks(path.read_text()):
        where = f"{path.relative_to(REPO)}:{lineno}"
        content = [l for l in body.splitlines() if l.strip()
                   and not l.strip().startswith("%%")]
        if not content:
            errors.append(f"{where}: empty mermaid block")
            continue
        head = content[0].strip().split()[0]
        if head not in MERMAID_TYPES:
            errors.append(f"{where}: unknown mermaid diagram type {head!r}")
        counts = {"(": 0, "[": 0, "{": 0}
        closers = {")": "(", "]": "[", "}": "{"}
        for line in content:
            for ch in strip_labels(line):
                if ch in counts:
                    counts[ch] += 1
                elif ch in closers:
                    counts[closers[ch]] -= 1
        bad = {k: v for k, v in counts.items() if v != 0}
        if bad:
            errors.append(f"{where}: unbalanced mermaid brackets {bad}")
        if head in ("graph", "flowchart"):
            if not any("-->" in l or "---" in l for l in content[1:]):
                errors.append(f"{where}: flowchart with no edges")
        if head == "sequenceDiagram":
            if not any("->>" in l or "-->>" in l for l in content[1:]):
                errors.append(f"{where}: sequence diagram with no messages")


def check_links(path: pathlib.Path, errors: list[str]) -> None:
    """Resolve every relative markdown link (and anchor) in one file."""
    text = path.read_text()
    slugs_cache: dict[pathlib.Path, set[str]] = {}

    def slugs_of(p: pathlib.Path) -> set[str]:
        if p not in slugs_cache:
            slugs_cache[p] = {slugify(h)
                              for h in HEADING_RE.findall(p.read_text())}
        return slugs_cache[p]

    for m in LINK_RE.finditer(text):
        target = m.group(2)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        where = f"{path.relative_to(REPO)}"
        ref, _, anchor = target.partition("#")
        dest = path if not ref else (path.parent / ref).resolve()
        if not dest.exists():
            errors.append(f"{where}: broken link {target!r}")
            continue
        if anchor and dest.suffix == ".md" and anchor not in slugs_of(dest):
            errors.append(f"{where}: missing anchor {target!r}")


def main() -> int:
    """Run all checks; print one line per problem."""
    errors: list[str] = []
    files = doc_files()
    if not (REPO / "docs").is_dir():
        errors.append("docs/ directory is missing")
    for f in files:
        check_mermaid(f, errors)
        check_links(f, errors)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: OK ({len(files)} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
