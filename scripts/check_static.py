#!/usr/bin/env python3
"""CI wrapper for the static-analysis suite (``repro.analysis``).

Runs every registered pass over the repository, writes the JSON report
(``ANALYSIS_REPORT.json`` by default — uploaded as a CI artifact), and
exits non-zero if any finding survived suppression.  Pure stdlib: the
analysis package never imports jax, so this check needs no runtime deps.

Usage: python scripts/check_static.py [--report PATH] [--select PASS ...]
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.__main__ import main as analysis_main  # noqa: E402


def main() -> int:
    """Run the suite repo-wide; print one summary line like its siblings."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--report", default="ANALYSIS_REPORT.json",
                    help="JSON report path (default: ANALYSIS_REPORT.json)")
    ap.add_argument("--select", action="append", metavar="PASS",
                    help="run only this pass (repeatable)")
    args = ap.parse_args()

    argv = ["--root", str(ROOT), "--report", args.report]
    for name in args.select or ():
        argv += ["--select", name]
    rc = analysis_main(argv)
    if rc == 0:
        print(f"check_static: OK (report: {args.report})")
    else:
        print("check_static: findings above must be fixed (or suppressed "
              "within budget)", file=sys.stderr)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
