#!/usr/bin/env python3
"""Public-API snapshot checker for `repro.api` and `repro.core`.

Collects every exported name (``__all__``) of the two public packages
plus the signatures of exported callables and the public methods of
exported classes, and diffs the result against the checked-in snapshot
``scripts/api_snapshot.txt``. An accidental rename, signature change or
dropped export fails CI's docs job (and tier-1, via tests/test_docs.py)
before any consumer notices.

    python scripts/check_api.py            # verify (exit 1 on drift)
    python scripts/check_api.py --update   # rewrite the snapshot

Intentional surface changes are made by committing the updated snapshot
alongside the code change, which makes API breaks reviewable diffs.
"""
from __future__ import annotations

import difflib
import enum
import inspect
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SNAPSHOT = REPO / "scripts" / "api_snapshot.txt"
MODULES = ("repro.analysis", "repro.api", "repro.core")

sys.path.insert(0, str(REPO / "src"))

_ADDR = re.compile(r"0x[0-9a-fA-F]+")


def _sig(obj) -> str:
    """``inspect.signature`` text with memory addresses normalized."""
    try:
        return _ADDR.sub("0x…", str(inspect.signature(obj)))
    except (TypeError, ValueError):
        return "(…)"


def _class_lines(qual: str, cls: type) -> list[str]:
    """Snapshot lines for one exported class: bases kind + public members."""
    lines = []
    if issubclass(cls, enum.Enum):
        members = ", ".join(m.name for m in cls)
        lines.append(f"{qual}: enum[{members}]")
        return lines
    import dataclasses

    if dataclasses.is_dataclass(cls):
        fields = ", ".join(f.name for f in dataclasses.fields(cls))
        lines.append(f"{qual}: dataclass({fields})")
    else:
        lines.append(f"{qual}: class{_sig(cls.__init__)}")
    for name, member in sorted(vars(cls).items()):
        if name.startswith("_"):
            continue
        if isinstance(member, property):
            lines.append(f"{qual}.{name}: property")
        elif isinstance(member, staticmethod):
            lines.append(f"{qual}.{name}{_sig(member.__func__)} [static]")
        elif isinstance(member, classmethod):
            lines.append(f"{qual}.{name}{_sig(member.__func__)} [classmethod]")
        elif inspect.isfunction(member):
            lines.append(f"{qual}.{name}{_sig(member)}")
    return lines


def snapshot_lines() -> list[str]:
    """The current public surface, one sorted line per entry."""
    import importlib

    lines: list[str] = []
    for mod_name in MODULES:
        mod = importlib.import_module(mod_name)
        exported = getattr(mod, "__all__", None)
        if exported is None:
            lines.append(f"{mod_name}: MISSING __all__")
            continue
        for name in sorted(exported):
            obj = getattr(mod, name, None)
            qual = f"{mod_name}.{name}"
            if obj is None:
                lines.append(f"{qual}: MISSING")
            elif inspect.isclass(obj):
                lines.extend(_class_lines(qual, obj))
            elif inspect.ismodule(obj):
                sub = ", ".join(sorted(getattr(obj, "__all__", ())))
                lines.append(f"{qual}: module[{sub}]")
            elif callable(obj):
                lines.append(f"{qual}{_sig(obj)}")
            else:
                lines.append(f"{qual}: constant[{type(obj).__name__}]")
    return lines


def main(argv: list[str]) -> int:
    """Verify or update the snapshot; returns the process exit code."""
    current = "\n".join(snapshot_lines()) + "\n"
    if "--update" in argv:
        SNAPSHOT.write_text(current)
        print(f"wrote {SNAPSHOT.relative_to(REPO)} "
              f"({len(current.splitlines())} entries)")
        return 0
    if not SNAPSHOT.exists():
        print(f"{SNAPSHOT.relative_to(REPO)} missing — run "
              f"`python scripts/check_api.py --update` and commit it",
              file=sys.stderr)
        return 1
    recorded = SNAPSHOT.read_text()
    if recorded == current:
        print(f"public API matches {SNAPSHOT.relative_to(REPO)} "
              f"({len(current.splitlines())} entries)")
        return 0
    diff = difflib.unified_diff(recorded.splitlines(), current.splitlines(),
                                "api_snapshot.txt (recorded)",
                                "public API (current)", lineterm="")
    for line in diff:
        print(line, file=sys.stderr)
    print("\npublic API drifted from the snapshot; if intentional, run "
          "`python scripts/check_api.py --update` and commit the diff",
          file=sys.stderr)
    return 1


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
