#!/usr/bin/env python3
"""Consolidated lint driver: every repo checker, one summary table.

Runs the five checkers in order — docs, docstrings, API surface, bench
schema, static analysis — failing fast: the first failure marks the
remaining checkers as skipped.  The bench-schema step is skipped (not
failed) when no ``BENCH_*.json`` artifacts exist, unless
``--require-bench`` is given (CI generates them first and passes it).

Usage: python scripts/lint.py [--require-bench] [--no-fail-fast]
"""
from __future__ import annotations

import argparse
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

BENCH_ARTIFACTS = ("BENCH_coexec.json", "BENCH_coexec_multi.json",
                   "BENCH_kernels.json", "BENCH_traffic.json",
                   "BENCH_cluster.json")

CHECKS = (
    ("docs", "check_docs.py", ()),
    ("docstrings", "check_docstrings.py", ()),
    ("api-surface", "check_api.py", ()),
    ("bench-schema", "check_bench_schema.py", BENCH_ARTIFACTS),
    ("static-analysis", "check_static.py", ()),
)


def _run(script: str, args: tuple) -> int:
    """Run one checker as a subprocess, streaming its output."""
    cmd = [sys.executable, str(ROOT / "scripts" / script), *args]
    return subprocess.run(cmd, cwd=ROOT).returncode


def main() -> int:
    """Run every checker; print the summary table; exit 1 on any failure."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--require-bench", action="store_true",
                    help="fail (instead of skip) when BENCH artifacts "
                         "are missing")
    ap.add_argument("--no-fail-fast", action="store_true",
                    help="keep running checkers after a failure")
    args = ap.parse_args()

    results = []
    failed = False
    for name, script, check_args in CHECKS:
        if failed and not args.no_fail_fast:
            results.append((name, "SKIP (fail-fast)"))
            continue
        if script == "check_bench_schema.py":
            missing = [a for a in BENCH_ARTIFACTS
                       if not (ROOT / a).exists()]
            if missing and not args.require_bench:
                results.append((name, "SKIP (no artifacts)"))
                continue
        print(f"== lint: {name} ({script}) ==", flush=True)
        rc = _run(script, check_args)
        results.append((name, "OK" if rc == 0 else f"FAIL (exit {rc})"))
        failed = failed or rc != 0

    width = max(len(n) for n, _ in results)
    print("\nlint summary")
    print("-" * (width + 24))
    for name, status in results:
        print(f"{name:<{width}}  {status}")
    print("-" * (width + 24))
    if failed:
        print("lint: FAILED", file=sys.stderr)
        return 1
    print("lint: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
