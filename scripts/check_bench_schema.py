#!/usr/bin/env python3
"""Schema check for the machine-readable benchmark artifacts.

Validates the JSON documents ``benchmarks.run`` writes
(``BENCH_coexec.json`` / ``BENCH_coexec_multi.json`` /
``BENCH_kernels.json`` / ``BENCH_traffic.json`` /
``BENCH_cluster.json``) so CI fails fast
when a row key is renamed or dropped — downstream perf-trajectory
tooling reads these artifacts across PRs, which makes their shape an
API. Stdlib-only, enforced in CI's docs job and in tier-1 via
tests/test_docs.py.

Checks per document:

* top level: ``schema_version`` (== 2), ``suite`` (a known suite key),
  ``spec`` (a mapping — the resolved CoexecSpec), ``rows`` (non-empty
  list);
* every row carries the full required key set for its suite (see
  ``REQUIRED``), with numeric values where numbers are expected.

    python scripts/check_bench_schema.py BENCH_coexec.json \\
        BENCH_coexec_multi.json BENCH_kernels.json BENCH_traffic.json \\
        BENCH_cluster.json
"""
from __future__ import annotations

import json
import numbers
import sys

SCHEMA_VERSION = 2

# required keys per row, by suite; all must be present in every row
REQUIRED: dict[str, dict[str, set]] = {
    "coexec": {
        "all": {"kind", "workload", "memory", "policy", "seconds",
                "packages", "dispatches", "h2d_copies", "d2h_copies",
                "pipeline_depth", "device_idle_frac",
                "host_overhead_frac"},
        "numeric": {"seconds", "packages", "dispatches", "h2d_copies",
                    "d2h_copies", "pipeline_depth", "device_idle_frac",
                    "host_overhead_frac"},
    },
    "coexec-multi": {
        "all": {"workload", "tenants", "admission", "fuse", "preempt",
                "policy", "p50_ms", "p99_ms", "fairness",
                "fairness_curve_mean", "fairness_curve_min", "packages",
                "fused_batches", "total_ms"},
        "numeric": {"tenants", "p50_ms", "p99_ms", "fairness",
                    "fairness_curve_mean", "fairness_curve_min",
                    "packages", "fused_batches", "total_ms"},
    },
    "kernels": {
        "all": {"kind", "kernel", "impl", "label", "size", "iters",
                "us_per_call"},
        "numeric": {"size", "iters", "us_per_call"},
    },
    "traffic": {
        "all": {"workload", "arrival", "tenants", "load", "admission",
                "preempt", "shed", "slo_ms", "arrivals", "admitted",
                "shed_count", "p50_ms", "p99_ms", "miss_rate",
                "shed_fraction", "packages", "fused_batches", "total_ms"},
        "numeric": {"tenants", "load", "arrivals", "admitted",
                    "shed_count", "p50_ms", "p99_ms", "miss_rate",
                    "shed_fraction", "packages", "fused_batches",
                    "total_ms"},
    },
    "cluster": {
        "all": {"name", "workload", "arrival", "admission", "load",
                "min_units", "max_units", "autoscale", "arrivals",
                "admitted", "shed_count", "completed", "lost",
                "duplicated", "reissued", "kills", "joins", "resizes",
                "p50_ms", "p99_ms"},
        "numeric": {"load", "min_units", "max_units", "arrivals",
                    "admitted", "shed_count", "completed", "lost",
                    "duplicated", "reissued", "kills", "joins",
                    "resizes", "p50_ms", "p99_ms"},
    },
}


def check_doc(path: str, doc) -> list[str]:
    """Validate one artifact document; returns error strings."""
    errors: list[str] = []

    def err(msg: str) -> None:
        errors.append(f"{path}: {msg}")

    if not isinstance(doc, dict):
        return [f"{path}: top level must be a JSON object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        err(f"schema_version must be {SCHEMA_VERSION}, "
            f"got {doc.get('schema_version')!r}")
    suite = doc.get("suite")
    if suite not in REQUIRED:
        err(f"suite must be one of {sorted(REQUIRED)}, got {suite!r}")
        return errors
    if not isinstance(doc.get("spec"), dict):
        err("spec must be the resolved CoexecSpec mapping")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        err("rows must be a non-empty list")
        return errors
    want = REQUIRED[suite]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            err(f"rows[{i}] is not an object")
            continue
        missing = sorted(want["all"] - set(row))
        if missing:
            err(f"rows[{i}] missing required key(s) {missing}")
        for key in sorted(want["numeric"] & set(row)):
            if not isinstance(row[key], numbers.Number) \
                    or isinstance(row[key], bool):
                err(f"rows[{i}][{key!r}] must be numeric, "
                    f"got {type(row[key]).__name__}")
    return errors


def main(argv: list[str]) -> int:
    """Validate every artifact path given; returns the exit code."""
    paths = argv or ["BENCH_coexec.json", "BENCH_coexec_multi.json",
                     "BENCH_kernels.json", "BENCH_traffic.json",
                     "BENCH_cluster.json"]
    errors: list[str] = []
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"{path}: unreadable ({e})")
            continue
        errors.extend(check_doc(path, doc))
    for e in errors:
        print(f"check_bench_schema: {e}", file=sys.stderr)
    if not errors:
        print(f"check_bench_schema: OK ({len(paths)} artifact(s))")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
