#!/usr/bin/env python3
"""pydocstyle-lite: docstring discipline for the public core API.

Stdlib-ast only (no imports of the package, no pip deps), enforced in
CI's docs job and in tier-1 via tests/test_docs.py. Two tiers:

* **Presence tier** — every public module / class / function / method in
  the checked modules has a docstring whose summary line ends in ``.``,
  ``:`` or ``?``. Names starting with ``_`` are exempt.
* **Sections tier** — the designated public API surface additionally
  documents its arguments / returns / raises: each entry lists required
  substrings (``Args:``, ``Returns:``, ``Raises:``, or named fields for
  dataclasses).

Exit status 0 = clean; 1 = violations (one line each on stderr).
"""
from __future__ import annotations

import ast
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
CORE = "src/repro/core"

API = "src/repro/api"

MODULES = [
    f"{API}/cli.py",
    f"{API}/registry.py",
    f"{API}/spec.py",
    f"{CORE}/admission.py",
    f"{CORE}/cluster.py",
    f"{CORE}/dataplane.py",
    f"{CORE}/energy.py",
    f"{CORE}/engine.py",
    f"{CORE}/exec.py",
    f"{CORE}/runtime.py",
    f"{CORE}/scheduler.py",
    f"{CORE}/sim.py",
    f"{CORE}/traffic.py",
]

# Public API surface that must carry full Args/Returns/Raises sections
# (or, for dataclasses, document every named field).
STRICT: dict[str, tuple[str, ...]] = {
    "admission.py::AdmissionConfig": ("policy", "fuse", "max_inflight",
                                      "quantum"),
    "admission.py::AdmissionController.admit": ("Args:",),
    "admission.py::AdmissionController.discard": ("Args:",),
    "admission.py::AdmissionController.flush": ("Args:",),
    "admission.py::AdmissionController.next_work": ("Args:", "Returns:"),
    "admission.py::jain_index": ("Args:", "Returns:", "Raises:"),
    "energy.py::EnergyReport": ("per_unit_J", "uncore_dram_J", "runtime_s"),
    "energy.py::PowerModel": ("busy_w", "idle_w", "uncore_dram_w"),
    "engine.py::CoexecEngine.submit": ("Args:", "Returns:", "Raises:"),
    "engine.py::LaunchHandle.exception": ("Args:", "Returns:", "Raises:"),
    "engine.py::LaunchHandle.result": ("Args:", "Returns:", "Raises:"),
    "cluster.py::Autoscaler.observe": ("Args:", "Returns:"),
    "cluster.py::FailurePlan.load": ("Args:", "Returns:"),
    "cluster.py::Supervisor.check": ("Args:", "Returns:"),
    "cluster.py::UnitPool.drain": ("Args:", "Returns:"),
    "cluster.py::UnitPool.grow": ("Args:", "Returns:"),
    "cluster.py::replay_trace_cluster": ("Args:", "Returns:"),
    "exec.py::Backend.dispatch": ("Args:",),
    "exec.py::ExecutionLoop.admit": ("Args:",),
    "exec.py::ExecutionLoop.complete": ("Args:",),
    "exec.py::ExecutionLoop.offer": ("Args:", "Returns:"),
    "exec.py::ExecutionLoop.pull": ("Args:", "Returns:"),
    "exec.py::ExecutionLoop.unit_joined": ("Args:",),
    "exec.py::ExecutionLoop.unit_lost": ("Args:", "Returns:"),
    "traffic.py::Trace.load": ("Args:", "Returns:"),
    "traffic.py::Trace.save": ("Args:",),
    "traffic.py::capacity_items_per_s": ("Args:", "Returns:"),
    "traffic.py::replay_trace_sim": ("Args:", "Returns:"),
    "traffic.py::synthesize_trace": ("Args:", "Returns:", "Raises:"),
    "runtime.py::CoexecutorRuntime.launch_async": ("Args:", "Returns:",
                                                   "Raises:"),
    "scheduler.py::Scheduler.next_package": ("Args:", "Returns:"),
    "sim.py::SimBackend.run": ("Args:",),
    "sim.py::simulate_multi": ("Args:", "Returns:", "Raises:"),
    "cli.py::add_spec_args": ("Args:",),
    "cli.py::args_from_spec": ("Args:", "Returns:"),
    "cli.py::spec_from_args": ("Args:", "Returns:"),
    "dataplane.py::ArgSpec": ("name", "role", "axis", "halo", "default"),
    "dataplane.py::CoexecKernel.bind": ("Args:", "Returns:", "Raises:"),
    "dataplane.py::DataPlane.execute": ("Args:",),
    "dataplane.py::DataPlane.plan": ("Args:", "Returns:", "Raises:"),
    "dataplane.py::DataPlaneCounters": ("dispatches", "h2d_copies",
                                        "d2h_copies"),
    "dataplane.py::as_coexec_kernel": ("Args:", "Returns:"),
    "dataplane.py::make_plane": ("Args:", "Returns:", "Raises:"),
    "registry.py::build_kernel": ("Args:", "Returns:", "Raises:"),
    "registry.py::build_scheduler": ("Args:", "Returns:", "Raises:"),
    "registry.py::build_workload": ("Args:", "Returns:", "Raises:"),
    "registry.py::kernel_demo_inputs": ("Args:", "Returns:", "Raises:"),
    "registry.py::register_kernel": ("Args:", "Returns:", "Raises:"),
    "registry.py::register_scheduler": ("Args:", "Returns:", "Raises:"),
    "registry.py::register_workload": ("Args:", "Returns:", "Raises:"),
    "registry.py::validate_scheduler_options": ("Args:", "Raises:"),
    "spec.py::CoexecSpec.from_dict": ("Args:", "Returns:", "Raises:"),
    "spec.py::CoexecSpec.validate": ("Returns:", "Raises:"),
    "spec.py::SchedulerSpec.build": ("Args:", "Returns:"),
    "spec.py::UnitsSpec.resolve_dist": ("Args:", "Returns:", "Raises:"),
}

SUMMARY_ENDINGS = (".", ":", "?")


def is_public(name: str) -> bool:
    """Public means no leading underscore (dunders included as private)."""
    return not name.startswith("_")


def summary_ok(doc: str) -> bool:
    """First non-empty docstring line must end like a sentence."""
    for line in doc.splitlines():
        if line.strip():
            return line.strip().endswith(SUMMARY_ENDINGS)
    return False


def walk_module(path: pathlib.Path, errors: list[str],
                strict_seen: set[str]) -> None:
    """Check one module's docstring discipline."""
    rel = path.name
    tree = ast.parse(path.read_text())

    def report(lineno: int, msg: str) -> None:
        errors.append(f"{path.relative_to(REPO)}:{lineno}: {msg}")

    def check_doc(node, qual: str) -> None:
        doc = ast.get_docstring(node)
        kind = type(node).__name__
        if not doc:
            report(node.lineno, f"missing docstring on {kind} {qual}")
            return
        if not summary_ok(doc):
            report(node.lineno,
                   f"{qual}: summary line must end with one of "
                   f"{SUMMARY_ENDINGS}")
        key = f"{rel}::{qual}"
        if key in STRICT:
            strict_seen.add(key)
            missing = [s for s in STRICT[key] if s not in doc]
            if missing:
                report(node.lineno,
                       f"{qual}: docstring missing required {missing}")

    if not ast.get_docstring(tree):
        report(1, "missing module docstring")

    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if is_public(node.name):
                check_doc(node, node.name)
        elif isinstance(node, ast.ClassDef) and is_public(node.name):
            check_doc(node, node.name)
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and is_public(sub.name):
                    check_doc(sub, f"{node.name}.{sub.name}")


def main() -> int:
    """Run the checker over every listed module."""
    errors: list[str] = []
    strict_seen: set[str] = set()
    for mod in MODULES:
        path = REPO / mod
        if not path.exists():
            errors.append(f"{mod}: checked module does not exist")
            continue
        walk_module(path, errors, strict_seen)
    for key in sorted(set(STRICT) - strict_seen):
        errors.append(f"{key}: strict-API entry not found in its module")
    for e in errors:
        print(f"check_docstrings: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docstrings: OK ({len(MODULES)} modules, "
              f"{len(STRICT)} strict entries)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
